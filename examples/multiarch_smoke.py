"""Drive every assigned architecture (--arch) through one reduced-config
forward/train step on CPU — the same model code the 512-chip dry-run lowers.

    PYTHONPATH=src python examples/multiarch_smoke.py --arch olmoe-1b-7b
    PYTHONPATH=src python examples/multiarch_smoke.py --all
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs


def _reduced_lm(cfg):
    kw = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2),
              d_ff=128 if cfg.moe is None else 0, vocab_size=512, head_dim=16,
              dtype=jnp.float32)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(cfg.moe, n_experts=4, top_k=2,
                                        d_expert=32, group_size=64)
    return dataclasses.replace(cfg, **kw)


def run_arch(arch_id: str):
    arch = get_arch(arch_id)
    rng = jax.random.PRNGKey(0)
    if arch.family == "lm":
        from repro.models.lm import init_lm, lm_loss

        cfg = _reduced_lm(arch.model_cfg)
        params = init_lm(rng, cfg)
        tokens = jax.random.randint(rng, (2, 64), 0, cfg.vocab_size)
        loss, aux = jax.jit(lambda p, t: lm_loss(p, cfg, t, t))(params, tokens)
        out = float(loss)
    elif arch.family == "gnn":
        from repro.data.graph import molecule_batch
        from repro.models.gnn import GraphBatch, init_schnet, schnet_loss

        cfg = dataclasses.replace(arch.model_cfg, n_interactions=2, d_hidden=16,
                                  n_rbf=8)
        params = init_schnet(rng, cfg)
        m = molecule_batch(4, 5, 8)
        g = GraphBatch(
            nodes=jnp.asarray(m["nodes"]), src=jnp.asarray(m["src"]),
            dst=jnp.asarray(m["dst"]), edge_dist=jnp.asarray(m["edge_dist"]),
            node_mask=jnp.asarray(m["node_mask"]),
            edge_mask=jnp.asarray(m["edge_mask"]),
            graph_id=jnp.asarray(m["graph_id"]), n_graphs=4,
            targets=jnp.asarray(m["targets"]),
        )
        loss, _ = jax.jit(lambda p: schnet_loss(p, cfg, g))(params)
        out = float(loss)
    elif arch.family == "recsys":
        from repro.models.recsys import bce_loss, init_recsys

        base = arch.model_cfg
        cfg = dataclasses.replace(
            base, vocab_sizes=(32,) * 6, embed_dim=8, row_pad_multiple=1,
            # keep MLP shapes consistent with the reduced embed_dim
            bot_mlp=(16, 8) if base.bot_mlp else (),
            top_mlp=(16,) * max(len(base.top_mlp) - 1, 1) + (1,)
            if base.interaction == "dot" else base.top_mlp and (16, 16),
        )
        params = init_recsys(rng, cfg)
        dense = jax.random.normal(rng, (16, cfg.n_dense))
        sparse = jax.random.randint(rng, (16, cfg.n_sparse), 0, 32)
        labels = jax.random.bernoulli(rng, 0.3, (16,)).astype(jnp.float32)
        loss, _ = jax.jit(lambda p: bce_loss(p, cfg, dense, sparse, labels))(params)
        out = float(loss)
    else:  # bert / dual encoder
        from repro.core.methods import init_state, make_update_fn
        from repro.core.types import ContrastiveConfig, RetrievalBatch
        from repro.models.bert import BertConfig
        from repro.models.towers import make_bert_dual_encoder
        from repro.optim.adamw import adamw

        enc = make_bert_dual_encoder(BertConfig(
            name="t", n_layers=2, d_model=32, n_heads=2, d_ff=64,
            vocab_size=128, max_position=32, dtype=jnp.float32))
        cfg = ContrastiveConfig(method="contaccum", accumulation_steps=2,
                                bank_size=16)
        tx = adamw(1e-3)
        st = init_state(rng, enc, tx, cfg)
        b = RetrievalBatch(
            query=jax.random.randint(rng, (8, 8), 0, 128),
            passage_pos=jax.random.randint(rng, (8, 16), 0, 128),
        )
        st, m = jax.jit(make_update_fn(enc, tx, cfg))(st, b)
        out = float(m.loss)
    assert np.isfinite(out), f"{arch_id}: non-finite loss {out}"
    print(f"{arch_id:26s} [{arch.family:6s}] one step OK, loss={out:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    for a in (list_archs() if args.all or not args.arch else [args.arch]):
        run_arch(a)


if __name__ == "__main__":
    main()
