"""End-to-end training driver: full production path (sharded loader, fault-
tolerant trainer with checkpoints, straggler watchdog, ContAccum update,
retrieval eval at the end).

Presets:
    --preset tiny   (default) CPU-runnable in ~2 min: 2-layer towers.
    --preset small  ~28M params/tower, a few hundred steps; CPU-slow but runs.
    --preset paper  bert-base towers, the paper's exact hyperparameters
                    (lr 2e-5, warmup 1237, clip 2.0, tau 1) — for accelerators.

    PYTHONPATH=src python examples/train_retriever.py --steps 200 \
        --checkpoint-dir /tmp/retriever_ckpt
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core.methods import (
    available_methods,
    build_step_program,
    init_state,
    make_update_fn,
    method_composition,
    method_needs_mesh,
    method_uses_banks,
)
from repro.core.precision import PRECISION_PRESETS
from repro.core.types import ContrastiveConfig, RetrievalBatch
from repro.data.loader import ShardedLoader
from repro.data.retrieval import SyntheticRetrievalCorpus
from repro.models.bert import BertConfig
from repro.models.towers import make_bert_dual_encoder
from repro.optim.adamw import adamw, chain, clip_by_global_norm
from repro.optim.schedules import linear_warmup_linear_decay
from repro.runtime.trainer import Trainer, TrainerConfig

PRESETS = {
    "tiny": BertConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                       d_ff=128, vocab_size=2000, max_position=64,
                       dtype=jnp.float32),
    # ~28M params/tower: an honest "end-to-end ~100M-class" CPU-runnable run
    # (both towers + optimizer state ≈ 340 MB of train state)
    "small": BertConfig(name="small", n_layers=6, d_model=512, n_heads=8,
                        d_ff=2048, vocab_size=30522, max_position=128,
                        dtype=jnp.float32),
    # the paper's backbone (110M/tower) with the paper's hyperparameters
    "paper": BertConfig(name="bert-base-uncased", n_layers=12, d_model=768,
                        n_heads=12, d_ff=3072, vocab_size=30522,
                        max_position=512, dtype=jnp.bfloat16, remat="full"),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--method", default="contaccum",
                    choices=[m for m in available_methods()
                             if not method_needs_mesh(m)],
                    help="any registered source x strategy composition this "
                         "single-program driver can build "
                         "(core/step_program.py; mesh-requiring methods "
                         "are excluded)")
    ap.add_argument("--loss-impl", default="dense", choices=["dense", "fused"],
                    help="loss backend (core/loss.py): 'dense' materializes "
                         "the logits block, 'fused' streams it through the "
                         "blocked Pallas kernel (interpret mode on CPU)")
    ap.add_argument("--precision", default=None,
                    choices=sorted(PRECISION_PRESETS),
                    help="PrecisionPolicy preset (core/precision.py): fp32 "
                         "reference, bf16 (bf16 compute copies, fp32 "
                         "masters), or bf16_banks (bf16 compute + bf16 bank "
                         "rings). Default keeps the preset's own dtypes "
                         "(the 'paper' preset is already bf16-compute)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--warmup-steps", type=int, default=None,
                    help="in-batch warm-up steps for from-scratch presets "
                         "(default: max(steps//2, 50))")
    ap.add_argument("--total-batch", type=int, default=64)
    ap.add_argument("--local-batch", type=int, default=8)
    ap.add_argument("--bank", type=int, default=256)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--corpus", type=int, default=2048)
    args = ap.parse_args(argv)

    bert = PRESETS[args.preset]
    lr = args.lr or (2e-5 if args.preset == "paper" else 1e-4)
    k = max(args.total_batch // args.local_batch, 1)
    _, backprop = method_composition(args.method)
    cfg = ContrastiveConfig(
        method=args.method,
        accumulation_steps=k if backprop != "direct" else 1,
        bank_size=args.bank if method_uses_banks(args.method) else 0,
        loss_impl=args.loss_impl,
        # --precision unset keeps the preset's own dtypes: the cfg policy
        # follows the preset's compute dtype so the loss / rep-cache don't
        # upcast the paper preset's bf16 reps back to fp32
        precision=args.precision
        or ("bf16" if bert.dtype == jnp.bfloat16 else "fp32"),
        temperature=1.0, grad_clip_norm=2.0,
    )
    enc = make_bert_dual_encoder(bert, precision=args.precision)
    tx = chain(
        clip_by_global_norm(cfg.grad_clip_norm),
        adamw(linear_warmup_linear_decay(
            lr, 1237 if args.preset == "paper" else args.steps // 10,
            args.steps,
        )),
    )
    program = build_step_program(enc, tx, cfg)
    update = jax.jit(program.update, donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)

    # Memory banks need an encoder whose representations drift slowly (the
    # paper fine-tunes pretrained BERT; see benchmarks/bench_regimes.py).
    # For from-scratch presets, warm the towers up with in-batch negatives.
    # Bank-free methods don't need it: warm up only when asked explicitly.
    wants_warmup = method_uses_banks(args.method) or (
        args.warmup_steps is not None and args.warmup_steps > 0
    )
    if args.preset != "paper" and wants_warmup:
        warm_cfg = ContrastiveConfig(method="dpr")
        warm_tx = chain(clip_by_global_norm(2.0), adamw(1e-3))
        warm = jax.jit(make_update_fn(enc, warm_tx, warm_cfg),
                       donate_argnums=(0,))
        wstate = init_state(jax.random.PRNGKey(1), enc, warm_tx, warm_cfg,
                            params=state.params)
        wcorpus = SyntheticRetrievalCorpus(
            n_passages=args.corpus, vocab_size=bert.vocab_size,
            q_len=16, p_len=32,
        )
        wloader = ShardedLoader(args.corpus, args.total_batch, seed=7)
        n_warm = (args.warmup_steps if args.warmup_steps is not None
                  else max(args.steps // 2, 50))
        for _ in range(n_warm):
            b = wcorpus.batch(wloader.next_indices())
            wstate, _ = warm(wstate, RetrievalBatch(
                query=jnp.asarray(b["query"]),
                passage_pos=jnp.asarray(b["passage_pos"]),
                passage_hard=jnp.asarray(b["passage_hard"]),
            ))
        state = init_state(jax.random.PRNGKey(0), enc, tx, cfg,
                           params=wstate.params)
    n_params = sum(
        int(x.size) for x in jax.tree_util.tree_leaves(state.params)
    )
    print(f"preset={args.preset} method={program.name} "
          f"({program.source.name} x {program.strategy.name}, "
          f"loss={cfg.loss_impl}): "
          f"{n_params/1e6:.1f}M params (both towers), "
          f"K={cfg.accumulation_steps}, N_mem={cfg.bank_size}")

    corpus = SyntheticRetrievalCorpus(
        n_passages=args.corpus, vocab_size=bert.vocab_size,
        q_len=16, p_len=32,
    )
    loader = ShardedLoader(args.corpus, args.total_batch, seed=0)

    def next_batch(step):
        b = corpus.batch(loader.next_indices())
        return RetrievalBatch(
            query=jnp.asarray(b["query"]),
            passage_pos=jnp.asarray(b["passage_pos"]),
            passage_hard=jnp.asarray(b["passage_hard"]),
        )

    trainer = Trainer(
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=max(args.steps // 4, 10),
            log_every=max(args.steps // 10, 1),
        ),
        update, next_batch, loader_state=loader.state,
    )
    state, report = trainer.run(state)

    from repro.evaluation import evaluate_topk
    metrics = evaluate_topk(enc, state.params, corpus)
    print(f"steps={report.steps_run} restarts={report.restarts} "
          f"stragglers={len(report.stragglers)}")
    print({m: round(v, 3) for m, v in metrics.items()})


if __name__ == "__main__":
    main()
