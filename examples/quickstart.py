"""Quickstart: train a dense retriever with ContAccum.

    PYTHONPATH=src python examples/quickstart.py

Two phases, mirroring the paper's setup (which fine-tunes PRETRAINED BERT —
a memory bank needs an encoder whose representations drift slowly, see
benchmarks/bench_regimes.py):

  1. warm up the towers with plain in-batch negatives (DPR objective);
  2. switch to ContAccum — dual memory banks + gradient accumulation —
     at a fine-tuning learning rate.
"""

import jax
import jax.numpy as jnp

from repro.core.methods import init_state, make_update_fn
from repro.core.types import ContrastiveConfig, RetrievalBatch
from repro.data.loader import ShardedLoader
from repro.data.retrieval import SyntheticRetrievalCorpus
from repro.models.bert import BertConfig
from repro.models.towers import make_bert_dual_encoder
from repro.optim.adamw import adamw, chain, clip_by_global_norm


def batches(corpus, loader):
    while True:
        b = corpus.batch(loader.next_indices())
        yield RetrievalBatch(
            query=jnp.asarray(b["query"]),
            passage_pos=jnp.asarray(b["passage_pos"]),
            passage_hard=jnp.asarray(b["passage_hard"]),
        )


def main(warm_steps: int = 100, steps: int = 100, n_passages: int = 1024):
    """Defaults reproduce the original walkthrough; the examples smoke test
    (tests/test_examples.py) shrinks the step counts so the drivers cannot
    silently rot against the StepProgram API."""
    # model: two small BERT towers (query + passage)
    encoder = make_bert_dual_encoder(BertConfig(
        name="bert-mini", n_layers=2, d_model=64, n_heads=4, d_ff=128,
        vocab_size=2000, max_position=64, dtype=jnp.float32,
    ))
    corpus = SyntheticRetrievalCorpus(n_passages=n_passages, vocab_size=2000,
                                      q_len=16, p_len=32)
    loader = ShardedLoader(corpus.n_passages, global_batch=32, seed=0)
    stream = batches(corpus, loader)

    # ---- phase 1: warm-up with in-batch negatives (stand-in for pretrain)
    warm_cfg = ContrastiveConfig(method="dpr")
    warm_tx = chain(clip_by_global_norm(2.0), adamw(1e-3))
    warm_update = jax.jit(make_update_fn(encoder, warm_tx, warm_cfg),
                          donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(0), encoder, warm_tx, warm_cfg)
    for step in range(warm_steps):
        state, m = warm_update(state, next(stream))
    print(f"warm-up done: loss {float(m.loss):.3f}")

    # ---- phase 2: ContAccum — the paper's method, spelled as an explicit
    # (negative source x backprop strategy) composition. method="contaccum"
    # is the same thing; other cells of the matrix: negatives in
    # {in_batch, gathered, dual_bank, passage_bank}, backprop in
    # {direct, scan, rep_cache} — e.g. dual_bank x rep_cache = "contcache".
    cfg = ContrastiveConfig(
        negatives="dual_bank",     # where negatives come from
        backprop="scan",           # how the backward pass is scheduled
        accumulation_steps=4,      # K       (N_local = 32/4 = 8)
        bank_size=128,             # N_memory for BOTH banks (dual symmetry)
        temperature=1.0,
        grad_clip_norm=2.0,
    )
    tx = chain(clip_by_global_norm(cfg.grad_clip_norm), adamw(1e-4))
    update = jax.jit(make_update_fn(encoder, tx, cfg), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(1), encoder, tx, cfg,
                       params=state.params)
    for step in range(steps):
        state, m = update(state, next(stream))
        if step % 20 == 0:
            print(f"step {step:3d}  loss {float(m.loss):.3f}  "
                  f"negatives/query {int(m.n_negatives)}  "
                  f"grad-norm ratio {float(m.grad_norm_ratio):.2f}")

    from repro.evaluation import evaluate_topk
    metrics = evaluate_topk(encoder, state.params, corpus)
    print({k: round(v, 3) for k, v in metrics.items()})


if __name__ == "__main__":
    main()
