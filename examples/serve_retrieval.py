"""Serve a retriever with dynamic batching: offline index build with the
passage tower, online query serving with request coalescing, blocked exact
top-k scoring.

    PYTHONPATH=src python examples/serve_retrieval.py
"""

import time

import jax
import numpy as np

from repro.data.retrieval import SyntheticRetrievalCorpus
from repro.models.bert import BertConfig, bert_encode, init_bert
from repro.runtime.server import build_index, make_retrieval_server
import jax.numpy as jnp


def main():
    cfg = BertConfig(name="bert-mini", n_layers=2, d_model=64, n_heads=4,
                     d_ff=128, vocab_size=2000, max_position=64,
                     dtype=jnp.float32)
    params = init_bert(jax.random.PRNGKey(0), cfg)
    corpus = SyntheticRetrievalCorpus(n_passages=2048, vocab_size=2000,
                                      q_len=16, p_len=32)

    # offline: encode the corpus with the passage tower
    t0 = time.time()
    index = build_index(lambda t: bert_encode(params, cfg, t),
                        corpus.passages, batch=256)
    print(f"index {index.shape} built in {time.time()-t0:.1f}s")

    # online: dynamic-batching server
    server = make_retrieval_server(
        lambda t: bert_encode(params, cfg, t), index, k=10, max_batch=16,
    ).start()
    try:
        t0 = time.time()
        futs = [server.submit(corpus.queries[i]) for i in range(128)]
        for f in futs:
            f.get(timeout=60)
        dt = time.time() - t0
        sizes = server.batch_sizes
        print(f"128 queries in {dt:.2f}s ({128/dt:.0f} qps); "
              f"coalesced batches: mean {np.mean(sizes):.1f}, "
              f"max {max(sizes)}, count {len(sizes)}")
    finally:
        server.stop()


if __name__ == "__main__":
    main()
