"""Serve a retriever through the Retriever API: offline index build with the
passage tower (policy index dtype), online query serving with request
coalescing, exact blocked top-k through a pluggable search backend.

    PYTHONPATH=src python examples/serve_retrieval.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.retrieval import SyntheticRetrievalCorpus
from repro.models.bert import BertConfig
from repro.models.towers import make_bert_dual_encoder
from repro.retrieval import Retriever, RetrieverConfig, make_server


def main():
    cfg = BertConfig(name="bert-mini", n_layers=2, d_model=64, n_heads=4,
                     d_ff=128, vocab_size=2000, max_position=64,
                     dtype=jnp.float32)
    enc = make_bert_dual_encoder(cfg)
    params = enc.init(jax.random.PRNGKey(0))
    corpus = SyntheticRetrievalCorpus(n_passages=2048, vocab_size=2000,
                                      q_len=16, p_len=32)

    # offline: encode the corpus with the passage tower into an IndexStore
    retriever = Retriever(
        enc, params, RetrieverConfig(top_k=10, search_impl="dense")
    )
    t0 = time.time()
    store = retriever.build_index(corpus.passages)
    print(f"index {store.reps.shape} ({str(store.reps.dtype)}) "
          f"built in {time.time()-t0:.1f}s")

    # online: dynamic-batching server over Retriever.search
    server = make_server(retriever, max_batch=16).start()
    try:
        t0 = time.time()
        futs = [server.submit(corpus.queries[i]) for i in range(128)]
        for f in futs:
            f.get(timeout=60)
        dt = time.time() - t0
        sizes = server.batch_sizes
        print(f"128 queries in {dt:.2f}s ({128/dt:.0f} qps); "
              f"coalesced batches: mean {np.mean(sizes):.1f}, "
              f"max {max(sizes)}, count {len(sizes)}")
    finally:
        server.stop()


if __name__ == "__main__":
    main()
