"""RPL001 dtype-literal containment and RPL006 fp32-stats contract.

The PrecisionPolicy (src/repro/core/precision.py) is the single owner of
every dtype decision: param/compute/bank/accum. RPL001 keeps it that way
statically — a bare float dtype literal anywhere else is either a policy
bypass (fix: route through the policy or the named contract constants
``STATS_DTYPE``/``MASTER_DTYPE``) or a deliberate, documented exception
(whitelist). RPL006 guards the sharpest corollary: statistics (loss,
accuracy, bank fill) must never be *reduced* in a low-precision dtype —
low-precision inputs only perturb the trajectory, low-precision statistics
change it (tests/test_precision.py pins the runtime half of this contract).
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, List, Optional, Set

from tools.reprolint.astutil import call_name, dotted_name, float_dtype_name
from tools.reprolint.engine import FileContext, RepoContext, Violation

#: the one module allowed to spell dtypes out — it IS the policy
_OWNER_SUFFIX = "core/precision.py"

_FLOAT_STRINGS = {
    "float32", "bfloat16", "float16", "float64", "double", "half",
    "f32", "bf16", "f16", "f64",
}

#: dtype-literal kwargs that *enforce* fp32 accumulation rather than bypass
#: the policy: preferred_element_type=jnp.float32 pins MXU/matmul accumulation
#: to the accum dtype and can never weaken precision — any other float dtype
#: there is a genuine violation (it would silently accumulate low-precision)
_ACCUM_KWARG = "preferred_element_type"


class DtypeLiteralRule:
    rule_id = "RPL001"
    name = "dtype-literal"
    doc = (
        "bare float dtype literals are only legal in core/precision.py "
        "(PrecisionPolicy owns every dtype) and the documented whitelist"
    )

    def check(self, fc: FileContext, repo: RepoContext) -> Iterable[Violation]:
        if fc.relpath.endswith(_OWNER_SUFFIX):
            return []
        out: List[Violation] = []
        exempt: Set[int] = set()
        for node in ast.walk(fc.tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if kw.arg == _ACCUM_KWARG and float_dtype_name(kw.value) == "float32":
                    exempt.add(id(kw.value))
                if kw.arg == "dtype" and isinstance(kw.value, ast.Constant):
                    if str(kw.value.value) in _FLOAT_STRINGS:
                        out.append(self._violation(fc, kw.value, repr(kw.value.value)))
        for node in ast.walk(fc.tree):
            dt = float_dtype_name(node)
            if dt is None or id(node) in exempt:
                continue
            out.append(self._violation(fc, node, f"{dotted_name(node)}", dt))
        return out

    def _violation(
        self, fc: FileContext, node: ast.AST, spelled: str, dt: Optional[str] = None
    ) -> Violation:
        dt = dt or spelled.strip("'\"")
        return Violation(
            path=fc.relpath,
            line=node.lineno,
            col=node.col_offset,
            rule=self.rule_id,
            message=(
                f"bare float dtype literal {spelled} — route through the "
                "PrecisionPolicy / the named contract dtypes in "
                "core/precision.py, or whitelist with a justification"
            ),
            data=(("dtype", dt),),
        )


_STAT_NAME_RE = re.compile(
    r"(^|_)(loss|losses|acc|accuracy|fill|metric|metrics|stat|stats)(_|$)",
    re.IGNORECASE,
)

_REDUCTIONS = {"mean", "sum", "average", "nanmean", "nansum"}

#: policy attributes that may resolve to a low-precision dtype at runtime —
#: casting a statistic to one of these before reduction breaks the contract
_SUSPECT_POLICY_ATTRS = {"compute_dtype", "bank_dtype", "param_dtype"}

_LOW_PRECISION = {"bfloat16", "float16", "half"}


def _is_reduction(node: ast.Call) -> bool:
    name = call_name(node)
    return name in _REDUCTIONS


def _bad_cast_target(node: ast.AST) -> Optional[str]:
    """Why a cast target is non-fp32: a low-precision literal, another
    array's runtime ``.dtype``, or a policy dtype that may be low-precision.
    fp32 / accum-dtype casts return None (they are the fix, not the bug)."""
    dt = float_dtype_name(node)
    if dt is not None:
        if dt in _LOW_PRECISION or dt.startswith("float8_"):
            return f"{dotted_name(node)}"
        return None  # fp32/fp64 literal cast — fine here, RPL001's business
    if isinstance(node, ast.Attribute):
        if node.attr == "dtype":
            return f"{dotted_name(node) or '<expr>.dtype'}"
        if node.attr in _SUSPECT_POLICY_ATTRS:
            return f"{dotted_name(node) or node.attr}"
    return None


class StatsDtypeRule:
    rule_id = "RPL006"
    name = "fp32-stats"
    doc = (
        "loss/accuracy/fill statistics must not be reduced in a "
        "non-fp32 dtype (the LossBackend accum-dtype contract)"
    )

    def check(self, fc: FileContext, repo: RepoContext) -> Iterable[Violation]:
        out: List[Violation] = []
        for node in ast.walk(fc.tree):
            for stat_name, expr in self._stat_bindings(node):
                out.extend(self._check_expr(fc, stat_name, expr))
        return out

    def _stat_bindings(self, node: ast.AST):
        """(statistic name, bound expression) pairs: assignments to
        stats-named targets and stats-named keywords of constructor calls
        (LossAux(loss=...), StepMetrics(accuracy=...))."""
        if isinstance(node, ast.Assign) and node.value is not None:
            for t in node.targets:
                if isinstance(t, ast.Name) and _STAT_NAME_RE.search(t.id):
                    yield t.id, node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            t = node.target
            if isinstance(t, ast.Name) and _STAT_NAME_RE.search(t.id):
                yield t.id, node.value
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg and _STAT_NAME_RE.search(kw.arg):
                    yield kw.arg, kw.value

    def _check_expr(
        self, fc: FileContext, stat_name: str, expr: ast.AST
    ) -> Iterable[Violation]:
        reductions = [
            n for n in ast.walk(expr) if isinstance(n, ast.Call) and _is_reduction(n)
        ]
        if not reductions:
            return
        for sub in ast.walk(expr):
            bad: Optional[str] = None
            where = sub
            if (
                isinstance(sub, ast.Call)
                and call_name(sub) == "astype"
                and sub.args
            ):
                bad = _bad_cast_target(sub.args[0])
            elif isinstance(sub, ast.Call) and _is_reduction(sub):
                for kw in sub.keywords:
                    if kw.arg == "dtype":
                        bad = _bad_cast_target(kw.value)
                        where = kw.value
            if bad is not None:
                yield Violation(
                    path=fc.relpath,
                    line=where.lineno,
                    col=where.col_offset,
                    rule=self.rule_id,
                    message=(
                        f"statistic '{stat_name}' is reduced under a non-fp32 "
                        f"cast ({bad}) — statistics must be computed in fp32/"
                        "accum_dtype (cast before the reduction; "
                        "see core/precision.py STATS_DTYPE)"
                    ),
                    data=(("stat", stat_name),),
                )
