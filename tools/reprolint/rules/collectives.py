"""RPL002 collective-axis validation.

Every named-axis collective (``psum``/``pmean``/``all_gather``/``ppermute``/
``psum_scatter``/``axis_index``/...) and every ``PartitionSpec`` literal must
name a mesh axis that is actually *declared* somewhere in the scanned tree —
``jax.make_mesh(shape, axes)`` / ``Mesh(devices, axes)`` call sites
(``launch/mesh.py`` and the per-driver debug meshes) are the ground truth.

A hardcoded axis string that drifts from the declared set (say ``"dp"``
after the mesh renamed to ``("data", "model")``) fails *inside* shard_map
tracing with an opaque XLA error at best, and silently no-ops a reduction at
worst; this rule catches it at lint time. Axis values that are variables
(``cfg.dp_axis``) are runtime-validated by jax and skipped here.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from tools.reprolint.astutil import call_name, dotted_name, string_elems
from tools.reprolint.engine import FileContext, RepoContext, Violation

#: collective -> positional index of its axis-name argument
_COLLECTIVES = {
    "psum": 1,
    "pmean": 1,
    "pmax": 1,
    "pmin": 1,
    "all_gather": 1,
    "ppermute": 1,
    "pshuffle": 1,
    "psum_scatter": 1,
    "all_to_all": 1,
    "axis_index": 0,
    "axis_size": 0,
}

_SPEC_NAMES = {"P", "PartitionSpec"}


class CollectiveAxisRule:
    rule_id = "RPL002"
    name = "collective-axis"
    doc = (
        "collective axis names and PartitionSpec literals must be mesh axes "
        "declared by a make_mesh/Mesh call site in the scanned tree"
    )

    def check(self, fc: FileContext, repo: RepoContext) -> Iterable[Violation]:
        out: List[Violation] = []
        for node in ast.walk(fc.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name in _COLLECTIVES:
                # guard against unrelated same-named methods: require a bare
                # name (from-import) or a jax/lax-ish attribute chain
                if isinstance(node.func, ast.Attribute):
                    base = dotted_name(node.func.value) or ""
                    if not (base == "lax" or base.endswith(".lax") or base == "jax"):
                        continue
                axis_node = None
                for kw in node.keywords:
                    if kw.arg == "axis_name":
                        axis_node = kw.value
                idx = _COLLECTIVES[name]
                if axis_node is None and len(node.args) > idx:
                    axis_node = node.args[idx]
                if axis_node is not None:
                    out.extend(self._check_axes(fc, repo, name, axis_node))
            elif name in _SPEC_NAMES:
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    out.extend(self._check_axes(fc, repo, name, arg))
        return out

    def _check_axes(
        self, fc: FileContext, repo: RepoContext, call: str, axis_node: ast.AST
    ) -> Iterable[Violation]:
        declared = repo.mesh_axes
        for axis in string_elems(axis_node):
            if axis in declared:
                continue
            known = ", ".join(sorted(declared)) if declared else "none declared"
            yield Violation(
                path=fc.relpath,
                line=axis_node.lineno,
                col=axis_node.col_offset,
                rule=self.rule_id,
                message=(
                    f"'{axis}' in {call}(...) is not a declared mesh axis "
                    f"(declared: {known}; declare it via make_mesh/Mesh or "
                    "pass --mesh-axes for targeted runs)"
                ),
                data=(("axis", axis),),
            )
