"""RPL002 collective-axis validation.

Every named-axis collective (``psum``/``pmean``/``all_gather``/``ppermute``/
``psum_scatter``/``axis_index``/...) and every ``PartitionSpec`` literal must
name a mesh axis that is actually *declared* somewhere in the scanned tree —
``jax.make_mesh(shape, axes)`` / ``Mesh(devices, axes)`` call sites
(``launch/mesh.py`` and the per-driver debug meshes) are the ground truth.

A hardcoded axis string that drifts from the declared set (say ``"dp"``
after the mesh renamed to ``("data", "model")``) fails *inside* shard_map
tracing with an opaque XLA error at best, and silently no-ops a reduction at
worst; this rule catches it at lint time. Axis values that are variables
(``cfg.dp_axis``) are runtime-validated by jax and skipped here.

The rule also validates *literal* ``ppermute`` perm tables. The ring-streamed
loss (core/loss.py) assumes every ppermute is a rotation: a single complete
cycle visiting every device on the axis exactly once, so that D hops return
each shard to its owner and the accumulated dP cotangents ride home. A
literal table that drops a pair, repeats a source, or splits into two cycles
deadlocks or silently misroutes shards at runtime — here it fails at lint
time: the table must be a permutation of the contiguous range 0..n-1 forming
one n-cycle, with n matching the axis size when ``jax.make_mesh`` declares
it unambiguously. Computed tables (``DistCtx.ring_perm``'s comprehension) are
skipped, like variable axis names.
"""

from __future__ import annotations

import ast
from typing import Iterable, List

from tools.reprolint.astutil import call_name, dotted_name, string_elems
from tools.reprolint.engine import FileContext, RepoContext, Violation

#: collective -> positional index of its axis-name argument
_COLLECTIVES = {
    "psum": 1,
    "pmean": 1,
    "pmax": 1,
    "pmin": 1,
    "all_gather": 1,
    "ppermute": 1,
    "pshuffle": 1,
    "psum_scatter": 1,
    "all_to_all": 1,
    "axis_index": 0,
    "axis_size": 0,
}

_SPEC_NAMES = {"P", "PartitionSpec"}


def _literal_perm(node: ast.AST):
    """[(src, dst), ...] when ``node`` is a literal list/tuple of int pairs,
    else None (comprehensions, names and calls are runtime facts)."""
    if not isinstance(node, (ast.List, ast.Tuple)):
        return None
    pairs = []
    for elt in node.elts:
        if not (isinstance(elt, (ast.Tuple, ast.List)) and len(elt.elts) == 2):
            return None
        pair = []
        for sub in elt.elts:
            if not (
                isinstance(sub, ast.Constant)
                and isinstance(sub.value, int)
                and not isinstance(sub.value, bool)
            ):
                return None
            pair.append(sub.value)
        pairs.append(tuple(pair))
    return pairs


def _perm_problem(pairs) -> "str | None":
    """Why a literal perm table is not a single complete ring rotation."""
    n = len(pairs)
    if n == 0:
        return "table is empty"
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    if len(set(srcs)) != n:
        return f"table repeats a source device (sources {sorted(srcs)})"
    if len(set(dsts)) != n:
        return f"table repeats a destination device (destinations {sorted(dsts)})"
    want = set(range(n))
    if set(srcs) != want or set(dsts) != want:
        return (
            f"devices are not the contiguous range 0..{n - 1} "
            f"(sources {sorted(set(srcs))}, destinations {sorted(set(dsts))})"
        )
    # permutation over 0..n-1; a ring rotation is one n-cycle, anything
    # shorter strands a subset of shards in a sub-ring
    nxt = dict(pairs)
    cur, hops = nxt[0], 1
    while cur != 0:
        cur = nxt[cur]
        hops += 1
    if hops != n:
        return (
            f"table is not a single complete cycle (device 0 returns after "
            f"{hops} hops, ring has {n} devices)"
        )
    return None


class CollectiveAxisRule:
    rule_id = "RPL002"
    name = "collective-axis"
    doc = (
        "collective axis names and PartitionSpec literals must be mesh axes "
        "declared by a make_mesh/Mesh call site in the scanned tree"
    )

    def check(self, fc: FileContext, repo: RepoContext) -> Iterable[Violation]:
        out: List[Violation] = []
        for node in ast.walk(fc.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name in _COLLECTIVES:
                # guard against unrelated same-named methods: require a bare
                # name (from-import) or a jax/lax-ish attribute chain
                if isinstance(node.func, ast.Attribute):
                    base = dotted_name(node.func.value) or ""
                    if not (base == "lax" or base.endswith(".lax") or base == "jax"):
                        continue
                axis_node = None
                for kw in node.keywords:
                    if kw.arg == "axis_name":
                        axis_node = kw.value
                idx = _COLLECTIVES[name]
                if axis_node is None and len(node.args) > idx:
                    axis_node = node.args[idx]
                if axis_node is not None:
                    out.extend(self._check_axes(fc, repo, name, axis_node))
                if name == "ppermute":
                    perm_node = None
                    for kw in node.keywords:
                        if kw.arg == "perm":
                            perm_node = kw.value
                    if perm_node is None and len(node.args) > 2:
                        perm_node = node.args[2]
                    if perm_node is not None:
                        out.extend(
                            self._check_perm(fc, repo, axis_node, perm_node)
                        )
            elif name in _SPEC_NAMES:
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    out.extend(self._check_axes(fc, repo, name, arg))
        return out

    def _check_perm(
        self,
        fc: FileContext,
        repo: RepoContext,
        axis_node: ast.AST,
        perm_node: ast.AST,
    ) -> Iterable[Violation]:
        pairs = _literal_perm(perm_node)
        if pairs is None:  # computed table — validated at trace time by jax
            return
        problem = _perm_problem(pairs)
        if problem is None and isinstance(axis_node, ast.Constant):
            declared = repo.mesh_axis_sizes.get(axis_node.value, set())
            if len(declared) == 1 and len(pairs) != next(iter(declared)):
                problem = (
                    f"table has {len(pairs)} entries but axis "
                    f"'{axis_node.value}' is declared with size "
                    f"{next(iter(declared))} — a partial ring deadlocks the "
                    "devices left out of the cycle"
                )
        if problem is not None:
            yield Violation(
                path=fc.relpath,
                line=perm_node.lineno,
                col=perm_node.col_offset,
                rule=self.rule_id,
                message=f"ppermute perm {problem}",
                data=(("check", "ppermute_perm"),),
            )

    def _check_axes(
        self, fc: FileContext, repo: RepoContext, call: str, axis_node: ast.AST
    ) -> Iterable[Violation]:
        declared = repo.mesh_axes
        for axis in string_elems(axis_node):
            if axis in declared:
                continue
            known = ", ".join(sorted(declared)) if declared else "none declared"
            yield Violation(
                path=fc.relpath,
                line=axis_node.lineno,
                col=axis_node.col_offset,
                rule=self.rule_id,
                message=(
                    f"'{axis}' in {call}(...) is not a declared mesh axis "
                    f"(declared: {known}; declare it via make_mesh/Mesh or "
                    "pass --mesh-axes for targeted runs)"
                ),
                data=(("axis", axis),),
            )
