"""RPL003 Pallas kernel registry and RPL004 kernel float-closure.

Kernel discipline in this repo (established by the fused-infonce PR and
kept by every kernel since): a Pallas kernel lives under
``src/repro/kernels/<name>/`` with the raw kernel module, an ``ops.py``
public surface, a ``ref.py`` pure-jnp reference implementation, and a parity
test in ``tests/`` that exercises kernel-vs-ref (interpret mode off-TPU).
RPL003 checks the registry statically: a ``pl.pallas_call`` outside that
layout, without a sibling ``ref.py``, or without any tests file mentioning
the kernel package name is a violation.

RPL004 guards a subtle correctness/retrace hazard: a kernel body that closes
over a Python float local of its builder bakes the value into the traced
kernel — invisibly versioned, retraced per value, and easy to desync from
the operand it was derived from. Scalars must be bound explicitly
(``functools.partial(kernel, inv_tau=...)``) or passed as operands.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.reprolint.astutil import (
    BUILTIN_NAMES,
    bound_names,
    call_name,
    is_float_constant_expr,
    module_level_names,
)
from tools.reprolint.engine import FileContext, RepoContext, Violation


def _pallas_calls(fc: FileContext) -> List[ast.Call]:
    return [
        n
        for n in ast.walk(fc.tree)
        if isinstance(n, ast.Call) and call_name(n) == "pallas_call"
    ]


class PallasRegistryRule:
    rule_id = "RPL003"
    name = "pallas-registry"
    doc = (
        "every pl.pallas_call lives under kernels/<name>/ with a sibling "
        "ref.py and a parity test in tests/ referencing the kernel name"
    )

    def check(self, fc: FileContext, repo: RepoContext) -> Iterable[Violation]:
        calls = _pallas_calls(fc)
        if not calls:
            return []
        first = min(calls, key=lambda c: c.lineno)
        out: List[Violation] = []

        parts = fc.relpath.split("/")
        if "kernels" not in parts or len(parts) < parts.index("kernels") + 3:
            out.append(
                self._violation(
                    fc,
                    first,
                    "pl.pallas_call outside the kernel registry — kernels "
                    "live under kernels/<name>/ with ref.py + ops.py + a "
                    "parity test",
                )
            )
            return out

        idx = parts.index("kernels")
        kernel_name = parts[idx + 1]
        kernel_dir = fc.path
        for _ in range(len(parts) - (idx + 2)):
            kernel_dir = kernel_dir.parent
        if not (kernel_dir / "ref.py").exists():
            out.append(
                self._violation(
                    fc,
                    first,
                    f"kernels/{kernel_name}/ has no ref.py — every kernel "
                    "needs a pure-jnp reference implementation for parity "
                    "testing",
                )
            )
        if repo.tests_dir is None:
            out.append(
                self._violation(
                    fc,
                    first,
                    "no tests/ directory found — cannot verify a parity test "
                    f"references '{kernel_name}' (pass --tests-dir)",
                )
            )
        elif kernel_name not in repo.tests_text:
            out.append(
                self._violation(
                    fc,
                    first,
                    f"no file under tests/ references '{kernel_name}' — every "
                    "kernel needs a kernel-vs-ref parity test",
                )
            )
        return out

    def _violation(self, fc: FileContext, node: ast.Call, msg: str) -> Violation:
        return Violation(
            path=fc.relpath,
            line=node.lineno,
            col=node.col_offset,
            rule=self.rule_id,
            message=msg,
        )


class PallasClosureRule:
    rule_id = "RPL004"
    name = "pallas-float-closure"
    doc = (
        "kernel bodies must not close over Python float locals of the "
        "builder — bind scalars via functools.partial or pass as operands"
    )

    def check(self, fc: FileContext, repo: RepoContext) -> Iterable[Violation]:
        out: List[Violation] = []
        defs: Dict[str, ast.FunctionDef] = {
            n.name: n
            for n in ast.walk(fc.tree)
            if isinstance(n, ast.FunctionDef)
        }
        module_names = module_level_names(fc.tree)
        for call in _pallas_calls(fc):
            kernel = self._kernel_def(call, defs)
            if kernel is None:
                continue
            float_locals = self._enclosing_float_names(fc, kernel)
            if not float_locals:
                continue
            free = self._free_loads(kernel)
            for name_node, name in free:
                if name in module_names or name in BUILTIN_NAMES:
                    continue
                if name in float_locals:
                    out.append(
                        Violation(
                            path=fc.relpath,
                            line=name_node.lineno,
                            col=name_node.col_offset,
                            rule=self.rule_id,
                            message=(
                                f"kernel '{kernel.name}' closes over Python "
                                f"float '{name}' from its builder — bind it "
                                "explicitly (functools.partial(kernel, "
                                f"{name}={name})) or pass it as an operand "
                                "(SMEM scalar)"
                            ),
                            data=(("name", name),),
                        )
                    )
        return out

    def _kernel_def(
        self, call: ast.Call, defs: Dict[str, ast.FunctionDef]
    ) -> Optional[ast.FunctionDef]:
        """Resolve pallas_call's kernel argument to a FunctionDef in this
        module. ``functools.partial(kernel, ...)`` bindings are explicit and
        deliberate — the partial'ed function is still checked for *other*
        (non-bound) float closures."""
        if not call.args:
            return None
        target = call.args[0]
        if isinstance(target, ast.Call) and call_name(target) == "partial":
            if target.args and isinstance(target.args[0], ast.Name):
                target = target.args[0]
            else:
                return None
        if isinstance(target, ast.Name):
            return defs.get(target.id)
        return None

    def _enclosing_float_names(
        self, fc: FileContext, kernel: ast.FunctionDef
    ) -> Set[str]:
        """Names bound to Python floats in functions enclosing the kernel
        def: ``x = 0.125`` assignments, float-annotated / float-defaulted
        parameters."""
        floats: Set[str] = set()
        for anc in fc.ancestors(kernel):
            if not isinstance(anc, ast.FunctionDef):
                continue
            for node in ast.walk(anc):
                if node is kernel or any(
                    a is kernel for a in fc.ancestors(node)
                ):
                    continue
                if isinstance(node, ast.Assign) and is_float_constant_expr(node.value):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            floats.add(t.id)
            args = anc.args
            defaults = list(args.defaults)
            pos = args.posonlyargs + args.args
            for param, default in zip(pos[len(pos) - len(defaults):], defaults):
                if isinstance(default, ast.Constant) and isinstance(
                    default.value, float
                ):
                    floats.add(param.arg)
            for param in pos + args.kwonlyargs:
                ann = param.annotation
                if isinstance(ann, ast.Name) and ann.id == "float":
                    floats.add(param.arg)
        return floats

    def _free_loads(self, fn: ast.FunctionDef) -> List[Tuple[ast.Name, str]]:
        bound = bound_names(fn)
        out: List[Tuple[ast.Name, str]] = []
        seen: Set[str] = set()
        for node in ast.walk(fn):
            if (
                isinstance(node, ast.Name)
                and isinstance(node.ctx, ast.Load)
                and node.id not in bound
                and node.id not in seen
            ):
                seen.add(node.id)
                out.append((node, node.id))
        return out
