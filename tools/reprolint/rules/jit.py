"""RPL005 jit hazards.

Inside a ``jax.jit``-ed (or ``shard_map``-ped) function, Python control flow
on traced values raises ``TracerBoolConversionError`` at runtime — but only
on the first call that reaches the branch, which for rarely-taken paths can
be deep into a training run. Host side effects (``print``, ``open``,
``np.random``, wall-clock reads) silently execute at *trace* time only, and
``global``/``nonlocal`` writes mutate Python state once per trace, not once
per step. All three are statically visible; this rule flags them at the
definition site.

Static arguments (``static_argnums``/``static_argnames``) are excluded from
the traced set, as are shape/dtype/ndim attribute probes, ``is None`` tests,
``isinstance``/``len`` checks — those are concrete under tracing.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from tools.reprolint.astutil import call_name, dotted_name, function_param_names
from tools.reprolint.engine import FileContext, RepoContext, Violation

_JIT_SUFFIXES = ("jit",)                    # jax.jit, jit, pjit
_SHARD_MAP_NAMES = {"shard_map", "sm"}      # get_shard_map() convention

#: calls that are host-only side effects under a trace
_HOST_CALLS = {"print", "input", "breakpoint", "open"}
_HOST_MODULES = {"np.random", "numpy.random", "random", "time"}

#: refresh entry points of the mining subsystem (repro/mining): the whole
#: refresh pipeline is host-side by construction — a corpus re-encode, a
#: worker thread, numpy table writes and an atomic buffer swap. Called from
#: jitted code it would run once at trace time and bake the then-current
#: table in as a compile-time constant. Matched as <...miner/mining...>.<entry>
#: so e.g. ``self.miner.refresh_async(...)`` or ``mining.refresh(...)`` fire
#: while an unrelated ``cache.refresh()`` does not.
_MINING_ENTRY_ATTRS = {"refresh", "refresh_async", "refresh_hook", "wait", "poll"}
_MINING_OWNER_HINTS = ("miner", "mining")

#: attribute probes that are static (concrete) on tracers
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "aval"}


def _is_jit_name(node: ast.AST) -> bool:
    name = dotted_name(node)
    return name is not None and name.split(".")[-1].endswith(_JIT_SUFFIXES)


def _static_args_from(call_or_dec: ast.Call) -> Tuple[Set[int], Set[str]]:
    nums: Set[int] = set()
    names: Set[str] = set()
    for kw in call_or_dec.keywords:
        if kw.arg == "static_argnums":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, int):
                    nums.add(sub.value)
        elif kw.arg == "static_argnames":
            for sub in ast.walk(kw.value):
                if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
                    names.add(sub.value)
    return nums, names


class JitHazardRule:
    rule_id = "RPL005"
    name = "jit-hazard"
    doc = (
        "no Python if/while on traced values, host side effects, or "
        "global/nonlocal mutation inside jitted/shard_mapped functions"
    )

    def check(self, fc: FileContext, repo: RepoContext) -> Iterable[Violation]:
        out: List[Violation] = []
        for fn, traced in self._jitted_functions(fc):
            out.extend(self._check_body(fc, fn, traced))
        return out

    # ------------------------------------------------------------ discovery
    def _jitted_functions(
        self, fc: FileContext
    ) -> Iterable[Tuple[ast.FunctionDef, Set[str]]]:
        defs: Dict[str, ast.FunctionDef] = {
            n.name: n for n in ast.walk(fc.tree) if isinstance(n, ast.FunctionDef)
        }
        seen: Set[int] = set()

        # decorator style: @jax.jit / @partial(jax.jit, static_argnums=...)
        for fn in defs.values():
            for dec in fn.decorator_list:
                static_nums: Set[int] = set()
                static_names: Set[str] = set()
                hit = False
                if _is_jit_name(dec) or (
                    isinstance(dec, ast.Name) and dec.id in _SHARD_MAP_NAMES
                ):
                    hit = True
                elif isinstance(dec, ast.Call):
                    if _is_jit_name(dec.func):
                        hit = True
                        static_nums, static_names = _static_args_from(dec)
                    elif call_name(dec) == "partial" and dec.args and _is_jit_name(
                        dec.args[0]
                    ):
                        hit = True
                        static_nums, static_names = _static_args_from(dec)
                if hit and id(fn) not in seen:
                    seen.add(id(fn))
                    yield fn, self._traced_params(fn, static_nums, static_names)

        # call style: jax.jit(f, ...) / sm(f, mesh=..., ...)
        for node in ast.walk(fc.tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            is_jit = _is_jit_name(node.func)
            is_sm = (
                isinstance(node.func, ast.Name) and node.func.id in _SHARD_MAP_NAMES
            ) or (
                isinstance(node.func, ast.Attribute)
                and node.func.attr in _SHARD_MAP_NAMES
            )
            if not (is_jit or is_sm):
                continue
            target = node.args[0]
            if not isinstance(target, ast.Name):
                continue
            fn = defs.get(target.id)
            if fn is None or id(fn) in seen:
                continue
            seen.add(id(fn))
            static_nums, static_names = _static_args_from(node)
            yield fn, self._traced_params(fn, static_nums, static_names)

    def _traced_params(
        self, fn: ast.FunctionDef, static_nums: Set[int], static_names: Set[str]
    ) -> Set[str]:
        params = function_param_names(fn)
        traced = {
            p
            for i, p in enumerate(params)
            if i not in static_nums and p not in static_names
        }
        return traced - {"self", "cls"}

    # ------------------------------------------------------------- checking
    def _check_body(
        self, fc: FileContext, fn: ast.FunctionDef, traced: Set[str]
    ) -> Iterable[Violation]:
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                name = self._dynamic_traced_ref(fc, node.test, traced)
                if name is not None:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield self._violation(
                        fc,
                        node,
                        f"Python `{kind}` on traced argument '{name}' of "
                        f"jitted '{fn.name}' — use jax.lax.cond/while_loop, "
                        "jnp.where, or mark the argument static",
                    )
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                names = ", ".join(node.names)
                yield self._violation(
                    fc,
                    node,
                    f"{type(node).__name__.lower()} write to '{names}' inside "
                    f"jitted '{fn.name}' runs at trace time only — return the "
                    "value or carry it in explicit state",
                )
            elif isinstance(node, ast.Call):
                host = self._host_call(node)
                if host is not None:
                    yield self._violation(
                        fc,
                        node,
                        f"host call {host}(...) inside jitted '{fn.name}' "
                        "executes at trace time only — use jax.debug.print / "
                        "jax.experimental.io_callback, or hoist it out",
                    )
                    continue
                mining = self._mining_refresh_call(node)
                if mining is not None:
                    yield self._violation(
                        fc,
                        node,
                        f"mining refresh entry point {mining}(...) inside "
                        f"jitted '{fn.name}' runs the host-side refresh "
                        "pipeline (corpus re-encode, worker thread, np table "
                        "swap) at trace time only, baking a stale negative "
                        "table in as a constant — drive the miner from a "
                        "trainer PeriodicHook outside the jitted step",
                    )

    def _violation(self, fc: FileContext, node: ast.AST, msg: str) -> Violation:
        return Violation(
            path=fc.relpath,
            line=node.lineno,
            col=node.col_offset,
            rule=self.rule_id,
            message=msg,
        )

    def _host_call(self, node: ast.Call) -> Optional[str]:
        if isinstance(node.func, ast.Name) and node.func.id in _HOST_CALLS:
            return node.func.id
        full = dotted_name(node.func)
        if full is not None:
            for mod in _HOST_MODULES:
                if full.startswith(mod + "."):
                    return full
        return None

    def _mining_refresh_call(self, node: ast.Call) -> Optional[str]:
        """``<owner>.<entry>`` where the owner chain names the miner — the
        mining-subsystem extension of the host-call net (see
        _MINING_ENTRY_ATTRS above)."""
        full = dotted_name(node.func)
        if full is None:
            return None
        parts = full.split(".")
        if len(parts) < 2 or parts[-1] not in _MINING_ENTRY_ATTRS:
            return None
        if any(h in p.lower() for p in parts[:-1] for h in _MINING_OWNER_HINTS):
            return full
        return None

    def _dynamic_traced_ref(
        self, fc: FileContext, test: ast.AST, traced: Set[str]
    ) -> Optional[str]:
        """First traced-parameter reference in ``test`` that is not a
        statically-resolvable probe (shape/dtype attrs, is-None, isinstance,
        len)."""
        for node in ast.walk(test):
            if not (
                isinstance(node, ast.Name)
                and isinstance(node.ctx, ast.Load)
                and node.id in traced
            ):
                continue
            if self._is_static_use(fc, node):
                continue
            return node.id
        return None

    def _is_static_use(self, fc: FileContext, name: ast.Name) -> bool:
        parent = fc.parent(name)
        if isinstance(parent, ast.Attribute) and parent.attr in _STATIC_ATTRS:
            return True
        if isinstance(parent, ast.Call):
            fname = call_name(parent)
            if fname in {"isinstance", "len", "callable", "hasattr", "getattr", "type"}:
                return True
        if isinstance(parent, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in parent.ops):
                return True
        # x.shape[0] == n: Name -> Attribute handled above; Name -> Subscript
        # of a static attr
        if isinstance(parent, ast.Subscript):
            gp = fc.parent(parent)
            if isinstance(gp, ast.Attribute) and gp.attr in _STATIC_ATTRS:
                return True
        return False
