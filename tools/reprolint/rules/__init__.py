"""Rule registry. Each rule object exposes ``rule_id``, ``name``, ``doc``
(one-line invariant statement shown by ``--list-rules``) and
``check(file_ctx, repo_ctx) -> Iterable[Violation]``."""

from tools.reprolint.rules.collectives import CollectiveAxisRule
from tools.reprolint.rules.dtypes import DtypeLiteralRule, StatsDtypeRule
from tools.reprolint.rules.jit import JitHazardRule
from tools.reprolint.rules.pallas import PallasClosureRule, PallasRegistryRule

ALL_RULES = [
    DtypeLiteralRule(),
    CollectiveAxisRule(),
    PallasRegistryRule(),
    PallasClosureRule(),
    JitHazardRule(),
    StatsDtypeRule(),
]

__all__ = ["ALL_RULES"]
