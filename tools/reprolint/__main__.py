"""CLI: ``python -m tools.reprolint src/ [tools/ tests/ ...]``.

Exit status 0 when the tree is clean (after inline suppressions and the
documented whitelist), 1 when violations or parse errors remain, 2 on bad
usage. ``--no-whitelist`` shows what the whitelist is absorbing;
``--explain-whitelist`` prints each entry with its justification.
"""

from __future__ import annotations

import argparse
import sys

from tools.reprolint.engine import iter_rules, run_reprolint
from tools.reprolint.whitelist import WHITELIST


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="repo-invariant static analysis (dtype contracts, "
        "collective axes, Pallas kernel discipline, jit hazards)",
    )
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--root", help="repo root (default: auto-detect)")
    ap.add_argument(
        "--tests-dir", help="tests directory for RPL003 parity-test checks"
    )
    ap.add_argument(
        "--mesh-axes",
        default="",
        help="comma-separated extra mesh axes to treat as declared "
        "(for targeted runs that do not scan the mesh-building modules)",
    )
    ap.add_argument(
        "--rules", default="", help="comma-separated rule ids to run (default all)"
    )
    ap.add_argument(
        "--no-whitelist",
        action="store_true",
        help="report whitelisted violations too",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="list rules and exit"
    )
    ap.add_argument(
        "--explain-whitelist",
        action="store_true",
        help="print whitelist entries with justifications and exit",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in iter_rules():
            print(f"{rule.rule_id}  {rule.name:<22} {rule.doc}")
        return 0
    if args.explain_whitelist:
        for e in WHITELIST:
            dts = ",".join(sorted(e.dtypes)) if e.dtypes else "any"
            print(f"{e.pattern}  [{', '.join(e.rules)}] dtypes={dts}")
            print(f"    {e.reason}")
        return 0
    if not args.paths:
        ap.print_usage(sys.stderr)
        print("error: no paths given", file=sys.stderr)
        return 2

    result = run_reprolint(
        args.paths,
        root=args.root,
        tests_dir=args.tests_dir,
        extra_axes=[a.strip() for a in args.mesh_axes.split(",") if a.strip()],
        use_whitelist=not args.no_whitelist,
        rules=[r.strip() for r in args.rules.split(",") if r.strip()] or None,
    )
    print(result.format())
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
