"""Small AST helpers shared by the rules (stdlib ``ast`` only)."""

from __future__ import annotations

import ast
import builtins
from typing import List, Optional, Set

#: float dtype attribute names the dtype rules recognize as "precision
#: decisions" (int dtypes — labels, ids, ring heads — are not policy-owned)
FLOAT_DTYPE_ATTRS = {"float32", "bfloat16", "float16", "float64", "double", "half"}

#: module spellings a dtype attribute may hang off
DTYPE_MODULES = {"jnp", "np", "numpy", "jax.numpy", "ml_dtypes", "mldtypes"}

BUILTIN_NAMES: Set[str] = set(dir(builtins))


def dotted_name(node: ast.AST) -> Optional[str]:
    """'jax.lax.psum' for nested Attribute/Name chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """The rightmost name of a call target: psum for jax.lax.psum(...)."""
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def float_dtype_name(node: ast.AST) -> Optional[str]:
    """'float32' if ``node`` is a float dtype literal (``jnp.float32``,
    ``np.bfloat16``, ``jnp.float8_e4m3fn``, ...), else None."""
    if isinstance(node, ast.Attribute):
        attr = node.attr
        if attr in FLOAT_DTYPE_ATTRS or attr.startswith("float8_"):
            base = dotted_name(node.value)
            if base is not None and (base in DTYPE_MODULES or base.endswith(".numpy")):
                return attr
    return None


def string_elems(node: ast.AST) -> List[str]:
    """String constants inside a Constant/Tuple/List (axis-name shapes)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in node.elts:
            out.extend(string_elems(elt))
        return out
    return []


def module_level_names(tree: ast.Module) -> Set[str]:
    """Names bound at module scope: imports, defs, classes, assignments."""
    names: Set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add((alias.asname or alias.name).split(".")[0])
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else [node.target]
            for t in targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name):
                        names.add(sub.id)
    return names


def function_param_names(fn: ast.FunctionDef) -> List[str]:
    a = fn.args
    params = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        params.append(a.vararg.arg)
    if a.kwarg:
        params.append(a.kwarg.arg)
    return params


def bound_names(fn: ast.FunctionDef) -> Set[str]:
    """Names assigned anywhere inside ``fn`` (incl. params, for-targets,
    with-targets, comprehension targets, nested defs)."""
    names: Set[str] = set(function_param_names(fn))
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, (ast.Store, ast.Del)):
            names.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            names.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                names.add((alias.asname or alias.name).split(".")[0])
    return names


def is_float_constant_expr(node: ast.AST) -> bool:
    """A Python-float compile-time constant: 0.125, 1.0 / 8, d ** -0.5 is NOT
    (names involved) — only literal arithmetic counts."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, float)
    if isinstance(node, ast.UnaryOp):
        return is_float_constant_expr(node.operand)
    if isinstance(node, ast.BinOp):
        sides = (node.left, node.right)
        if all(
            isinstance(s, ast.Constant) and isinstance(s.value, (int, float))
            for s in sides
        ):
            return any(isinstance(s.value, float) for s in sides) or isinstance(
                node.op, ast.Div
            )
    return False
