"""File-scoped whitelist for reprolint.

Every entry is a deliberate, documented exception to a rule — the goal is
for this file to stay *small* and for each ``reason`` to read as a design
note, not an excuse. Entries can be dtype-scoped: an entry that allows only
``{"float32"}`` still fires on a stray ``bfloat16`` literal in the same
file, so whitelisting a file does not turn the rule off there.

Patterns are matched with ``fnmatch`` against the repo-relative POSIX path
(``src/repro/optim/adamw.py``); a pattern without a slash matches any path
suffix component-wise via ``*/<pattern>``.
"""

from __future__ import annotations

from dataclasses import dataclass
from fnmatch import fnmatch
from typing import FrozenSet, Optional, Sequence, Tuple

from tools.reprolint.engine import Violation


@dataclass(frozen=True)
class WhitelistEntry:
    #: fnmatch pattern over the repo-relative path
    pattern: str
    #: rule ids this entry covers, e.g. ("RPL001",)
    rules: Tuple[str, ...]
    #: why the exception exists — shown by ``--explain-whitelist``
    reason: str
    #: for RPL001: the only dtype literals the entry permits. None = any.
    dtypes: Optional[FrozenSet[str]] = None

    def covers(self, v: Violation) -> bool:
        if v.rule not in self.rules:
            return False
        pat = self.pattern if "/" in self.pattern else "*/" + self.pattern
        if not (fnmatch(v.path, pat) or fnmatch(v.path, self.pattern)):
            return False
        if self.dtypes is not None:
            dt = v.get("dtype")
            if dt is not None and dt not in self.dtypes:
                return False
        return True


_FP32 = frozenset({"float32"})
_FP32_BF16 = frozenset({"float32", "bfloat16"})

WHITELIST: Tuple[WhitelistEntry, ...] = (
    WhitelistEntry(
        pattern="src/repro/optim/*.py",
        rules=("RPL001",),
        dtypes=_FP32,
        reason=(
            "The optimizer IS the fp32-master-weight contract: AdamW moments "
            "and master params are pinned fp32 by design (PAPER.md §3; "
            "tests/test_precision.py). It cannot import core.precision — "
            "repro.core.__init__ imports step_program which imports "
            "repro.optim, so the import would cycle through a partially "
            "initialised package."
        ),
    ),
    WhitelistEntry(
        pattern="src/repro/optim/compression.py",
        rules=("RPL001",),
        dtypes=_FP32_BF16,
        reason=(
            "Gradient wire-compression exists to move bf16 over the "
            "interconnect and decompress back to fp32 masters — both dtypes "
            "are the module's subject matter, not a policy bypass."
        ),
    ),
    WhitelistEntry(
        pattern="src/repro/common/treemath.py",
        rules=("RPL001",),
        dtypes=_FP32,
        reason=(
            "Pure tree math (global-norm etc.) accumulates in fp32 as a "
            "fixed numeric contract; same core.precision import cycle as "
            "optim/ (step_program -> optim -> common.treemath)."
        ),
    ),
    WhitelistEntry(
        pattern="src/repro/kernels/*",
        rules=("RPL001",),
        dtypes=_FP32,
        reason=(
            "Inside Pallas kernels fp32 VMEM scratch and fp32 "
            "ShapeDtypeStruct outputs ARE the accumulation contract the "
            "kernels implement (accumulate-in-fp32 regardless of input "
            "dtype). Input dtypes still flow in from the policy via ops.py; "
            "a bf16 literal here would (correctly) still fail the lint."
        ),
    ),
    WhitelistEntry(
        pattern="src/repro/configs/*.py",
        rules=("RPL001",),
        dtypes=_FP32_BF16,
        reason=(
            "Per-architecture preset tables are where human-readable "
            "precision choices are *declared* (bf16 compute for the large "
            "towers, fp32 for debug) before resolve_precision turns them "
            "into a policy — declaration sites, not bypasses."
        ),
    ),
    WhitelistEntry(
        pattern="src/repro/models/*.py",
        rules=("RPL001",),
        dtypes=_FP32,
        reason=(
            "Model numeric cores keep documented fp32 islands (attention "
            "softmax, layernorm variance, logit scaling) independent of the "
            "compute dtype — the islands are load-bearing for bf16 parity "
            "(tests/test_bf16_parity.py). Compute-dtype selection still "
            "comes from the policy via configs."
        ),
    ),
    WhitelistEntry(
        pattern="src/repro/data/*.py",
        rules=("RPL001",),
        dtypes=_FP32,
        reason=(
            "Host-side synthetic-data generation (numpy, never jitted): fp32 "
            "feature arrays are the wire format handed to device_put; the "
            "on-device compute-dtype cast is the encoders' policy cast, not "
            "the loader's concern."
        ),
    ),
    WhitelistEntry(
        pattern="src/repro/mining/*.py",
        rules=("RPL001",),
        dtypes=_FP32,
        reason=(
            "The mining refresh pipeline is deliberately host-side (numpy "
            "id tables, a worker thread, an atomic buffer swap — never "
            "jitted): its fp32 score scratch mirrors the SearchBackend's "
            "always-fp32 score contract on the host. On-device dtypes still "
            "come from MinerConfig's precision passthrough to "
            "RetrieverConfig; RPL005's mining extension separately flags "
            "any jitted caller reaching these entry points."
        ),
    ),
    WhitelistEntry(
        pattern="src/repro/launch/steps.py",
        rules=("RPL001",),
        dtypes=_FP32_BF16,
        reason=(
            "Dry-run step descriptions embed concrete dtype metadata for "
            "shape/memory accounting printouts; nothing numeric runs here."
        ),
    ),
)


def whitelist_covers(
    entries: Sequence[WhitelistEntry], v: Violation
) -> Optional[WhitelistEntry]:
    for e in entries:
        if e.covers(v):
            return e
    return None
