"""Rule engine: file collection, suppression comments, whitelist, reporting.

The engine is deliberately stdlib-only (``ast`` + ``tokenize``): it must run
in every environment the repo does, including the CI static-analysis job and
bare containers without the dev extras.

Flow: collect ``*.py`` files -> parse each into a ``FileContext`` (AST,
parent links, suppression table) -> build the ``RepoContext`` (declared mesh
axes, tests corpus) -> run every registered rule -> drop violations covered
by an inline suppression or a whitelist entry -> report.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: rules may suppress with ``# reprolint: disable=RPL001`` (same line) or
#: ``# reprolint: disable-file=RPL001,RPL002`` (first _FILE_SCOPE_LINES lines)
_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*(disable-file|disable)\s*=\s*([A-Za-z0-9_*,\s]+)"
)
_FILE_SCOPE_LINES = 15


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: ``path:line:col: rule message``. ``data`` carries
    rule-specific details the whitelist can scope on (e.g. the dtype name
    for RPL001)."""

    path: str
    line: int
    col: int
    rule: str
    message: str
    data: Tuple[Tuple[str, str], ...] = ()

    def get(self, key: str) -> Optional[str]:
        for k, v in self.data:
            if k == key:
                return v
        return None

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


class FileContext:
    """One parsed source file plus the lookups every rule needs."""

    def __init__(self, path: Path, relpath: str, source: str):
        self.path = path
        self.relpath = relpath
        self.source = source
        self.tree = ast.parse(source, filename=str(path))
        self.parents: Dict[int, ast.AST] = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[id(child)] = parent
        self.line_suppressions: Dict[int, Set[str]] = {}
        self.file_suppressions: Set[str] = set()
        self._collect_suppressions()

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self.parents.get(id(node))

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def _collect_suppressions(self) -> None:
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.source).readline)
            comments = [
                (t.start[0], t.string) for t in tokens if t.type == tokenize.COMMENT
            ]
        except tokenize.TokenizeError:  # pragma: no cover - ast already parsed
            comments = []
        for line, text in comments:
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            scope, rules = m.groups()
            ids = {r.strip() for r in rules.split(",") if r.strip()}
            if scope == "disable-file":
                if line <= _FILE_SCOPE_LINES:
                    self.file_suppressions |= ids
            else:
                self.line_suppressions.setdefault(line, set()).update(ids)

    def suppressed(self, violation: Violation) -> bool:
        if {"*", violation.rule} & self.file_suppressions:
            return True
        at_line = self.line_suppressions.get(violation.line, set())
        return bool({"*", violation.rule} & at_line)


class RepoContext:
    """Cross-file facts: the declared mesh axes and the tests corpus."""

    def __init__(
        self,
        root: Path,
        files: Sequence[FileContext],
        tests_dir: Optional[Path],
        extra_axes: Sequence[str] = (),
    ):
        self.root = root
        self.files = list(files)
        self.tests_dir = tests_dir
        self.mesh_axes: Set[str] = set(extra_axes)
        self.mesh_axis_sizes: Dict[str, Set[int]] = {}
        for fc in self.files:
            self.mesh_axes |= _declared_mesh_axes(fc.tree)
            for axis, sizes in _declared_axis_sizes(fc.tree).items():
                self.mesh_axis_sizes.setdefault(axis, set()).update(sizes)
        self.tests_text = ""
        if tests_dir is not None and tests_dir.is_dir():
            self.tests_text = "\n".join(
                p.read_text(encoding="utf-8", errors="replace")
                for p in sorted(tests_dir.rglob("*.py"))
            )


def _string_elems(node: ast.AST) -> List[str]:
    """String constants inside a Constant/Tuple/List node (axis-name shapes)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in node.elts:
            out.extend(_string_elems(elt))
        return out
    return []


def _declared_mesh_axes(tree: ast.Module) -> Set[str]:
    """Axis names declared by ``jax.make_mesh(shape, axes)`` / ``Mesh(devs,
    axes)`` literal tuples anywhere in the file. These calls are the ground
    truth RPL002 validates every axis string against."""
    axes: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node.func)
        if name == "make_mesh":
            target = None
            if len(node.args) >= 2:
                target = node.args[1]
            for kw in node.keywords:
                if kw.arg == "axis_names":
                    target = kw.value
            if target is not None:
                axes |= set(_string_elems(target))
        elif name == "Mesh" and len(node.args) >= 2:
            axes |= set(_string_elems(node.args[1]))
    return axes


def _int_elems(node: ast.AST) -> Optional[List[int]]:
    """Int constants of a literal Tuple/List/Constant; None when any element
    is computed (those shapes are runtime facts, not lintable ground truth)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[int] = []
        for elt in node.elts:
            sub = _int_elems(elt)
            if sub is None:
                return None
            out.extend(sub)
        return out
    return None


def _declared_axis_sizes(tree: ast.Module) -> Dict[str, Set[int]]:
    """axis name -> sizes it is declared with, from ``jax.make_mesh(shape,
    axes)`` call sites whose shape is a literal int tuple. An axis may carry
    several sizes across debug meshes; RPL002's ppermute perm check only
    binds when the declared size is unambiguous."""
    sizes: Dict[str, Set[int]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or _call_name(node.func) != "make_mesh":
            continue
        shape = node.args[0] if node.args else None
        names = node.args[1] if len(node.args) >= 2 else None
        for kw in node.keywords:
            if kw.arg == "axis_shapes":
                shape = kw.value
            elif kw.arg == "axis_names":
                names = kw.value
        if shape is None or names is None:
            continue
        dims = _int_elems(shape)
        axes = _string_elems(names)
        if dims is None or len(dims) != len(axes):
            continue
        for axis, dim in zip(axes, dims):
            sizes.setdefault(axis, set()).add(dim)
    return sizes


def _call_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


@dataclasses.dataclass
class LintResult:
    violations: List[Violation]
    files_scanned: int
    suppressed: int
    whitelisted: int
    parse_errors: List[str]

    @property
    def ok(self) -> bool:
        return not self.violations and not self.parse_errors

    def format(self) -> str:
        lines = [v.format() for v in self.violations]
        lines.extend(f"parse error: {e}" for e in self.parse_errors)
        lines.append(
            f"reprolint: {self.files_scanned} files, "
            f"{len(self.violations)} violations "
            f"({self.suppressed} suppressed inline, "
            f"{self.whitelisted} whitelisted)"
        )
        return "\n".join(lines)


def iter_rules():
    """All registered rules (imported lazily: rules import this module)."""
    from tools.reprolint.rules import ALL_RULES

    return list(ALL_RULES)


def _collect_files(paths: Sequence[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        if p.is_dir():
            out.extend(
                f for f in sorted(p.rglob("*.py")) if "__pycache__" not in f.parts
            )
        elif p.suffix == ".py":
            out.append(p)
    seen: Set[Path] = set()
    unique = []
    for f in out:
        r = f.resolve()
        if r not in seen:
            seen.add(r)
            unique.append(f)
    return unique


def _find_root(paths: Sequence[Path]) -> Path:
    """Nearest ancestor of the first path that looks like the repo root."""
    start = paths[0].resolve()
    cur = start if start.is_dir() else start.parent
    for cand in [cur, *cur.parents]:
        if any((cand / marker).exists() for marker in (".git", "pytest.ini", "ROADMAP.md")):
            return cand
    return cur


def run_reprolint(
    paths: Sequence[str],
    *,
    root: Optional[str] = None,
    tests_dir: Optional[str] = None,
    extra_axes: Sequence[str] = (),
    whitelist: Optional[Sequence[Any]] = None,
    use_whitelist: bool = True,
    rules: Optional[Sequence[str]] = None,
) -> LintResult:
    """Lint ``paths`` (files or directories) and return the filtered result.

    ``whitelist=None`` uses the repo whitelist (tools/reprolint/whitelist.py)
    when ``use_whitelist`` is set; pass an explicit list to scope tests.
    ``rules`` restricts to a subset of rule ids.
    """
    from tools.reprolint.whitelist import WHITELIST, whitelist_covers

    path_objs = [Path(p) for p in paths]
    root_path = Path(root).resolve() if root else _find_root(path_objs)
    tdir = Path(tests_dir) if tests_dir else root_path / "tests"

    files: List[FileContext] = []
    parse_errors: List[str] = []
    for f in _collect_files(path_objs):
        try:
            rel = f.resolve().relative_to(root_path).as_posix()
        except ValueError:
            rel = f.resolve().as_posix()
        try:
            files.append(FileContext(f, rel, f.read_text(encoding="utf-8")))
        except SyntaxError as e:
            parse_errors.append(f"{rel}:{e.lineno}: {e.msg}")

    repo = RepoContext(root_path, files, tdir if tdir.is_dir() else None, extra_axes)

    active = iter_rules()
    if rules is not None:
        wanted = set(rules)
        active = [r for r in active if r.rule_id in wanted]

    entries = WHITELIST if whitelist is None else list(whitelist)
    raw: List[Violation] = []
    for fc in files:
        for rule in active:
            raw.extend(rule.check(fc, repo))

    kept: List[Violation] = []
    n_suppressed = 0
    n_whitelisted = 0
    by_path = {fc.relpath: fc for fc in files}
    for v in sorted(raw, key=lambda v: (v.path, v.line, v.col, v.rule)):
        fc = by_path[v.path]
        if fc.suppressed(v):
            n_suppressed += 1
            continue
        if use_whitelist and whitelist_covers(entries, v):
            n_whitelisted += 1
            continue
        kept.append(v)

    return LintResult(
        violations=kept,
        files_scanned=len(files),
        suppressed=n_suppressed,
        whitelisted=n_whitelisted,
        parse_errors=parse_errors,
    )
