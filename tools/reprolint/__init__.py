"""reprolint: repo-invariant static analysis for the repro codebase.

The runtime test suites pin this repo's hard invariants (fp32 stats, the
PrecisionPolicy dtype ownership, mesh-axis-named collectives, the Pallas
kernel registry discipline) — but only for the code paths a 3-step
trajectory test happens to execute. reprolint checks the same invariants
*statically*, over every file, at lint time:

  RPL001  dtype-literal containment — bare float dtype literals
          (``jnp.float32``/``jnp.bfloat16``/...) are legal only in
          ``core/precision.py`` and the documented whitelist; everything
          else routes dtype decisions through the PrecisionPolicy.
  RPL002  collective-axis validation — axis names in ``psum``/``pmean``/
          ``all_gather``/``ppermute``/``psum_scatter``/``axis_index`` and in
          ``PartitionSpec``/``shard_map`` specs must be mesh axes actually
          declared (``launch/mesh.py`` / ``Mesh``/``make_mesh`` call sites).
  RPL003  Pallas kernel registry — every ``pl.pallas_call`` site lives under
          ``kernels/<name>/`` with a sibling ``ref.py`` and a parity test in
          ``tests/`` that references the kernel by name.
  RPL004  Pallas float closure — kernel bodies must not close over Python
          float locals of the builder (pass them as explicit
          ``functools.partial`` bindings or operands instead).
  RPL005  jit hazards — Python ``if``/``while`` on traced arguments, host
          side effects (``print``/``open``/``np.random``/wall-clock), and
          ``global``/``nonlocal`` mutation inside jitted / shard_mapped
          functions.
  RPL006  fp32-stats contract — loss/accuracy/fill statistics must not be
          reduced in a non-fp32 dtype (the LossBackend accum-dtype contract).

Run ``python -m tools.reprolint src/`` (CI runs it in the static-analysis
job). Suppress a single line with ``# reprolint: disable=RPL001`` (comma
for several rules), a whole file with ``# reprolint: disable-file=RPL001``
in its first 15 lines; repo-wide exemptions live in
``tools/reprolint/whitelist.py`` and each carries a written justification.
"""

from tools.reprolint.engine import (  # noqa: F401  (public API)
    LintResult,
    Violation,
    iter_rules,
    run_reprolint,
)
