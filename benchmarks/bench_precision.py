"""PrecisionPolicy memory/speed sweep: fp32 vs bf16 vs bf16_banks,
replicated and sharded banks (suite ``precision``).

For each (policy, bank layout) the harness trains the paper's method
(contaccum) for a few steps on 8 forced host-platform devices under the
shard_map StepProgram path and reports:

  * per-device persistent bank bytes — the axis the policy exists to cut:
    fp32 replicated = (N_q+N_p)·d·4 on every chip; bf16_banks halves it,
    sharding divides by D, and the two compose to /(2·D);
  * per-evaluation representation bytes (compute-dtype activations: the
    local chunk's q/p/hard reps plus the gathered bank column block — the
    rep_cache store and the loss inputs scale with this);
  * mean step wall time (host-platform CPU: a sanity signal, not a TPU
    number — bf16 matmuls on CPU are emulated and often *slower*).

Also emits ``precision/bank_reduction_vs_fp32_pct`` rows: the acceptance
criterion is >= 40% per-device bank-byte reduction for bf16_banks vs the
fp32 replicated baseline (the measured value is 50%, and 93.75% combined
with 8-way sharding).

Runs in a subprocess because the 8-device host platform must be forced via
XLA_FLAGS before jax is first imported (same isolation pattern as
benchmarks/bench_distributed.py).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from typing import List, Tuple

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import time
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import (
        ContrastiveConfig, RetrievalBatch, bank_bytes_per_device,
        get_shard_map, resolve_precision,
    )
    from repro.core.methods import build_step_program, init_state
    from repro.distribution.sharding import contrastive_state_spec
    from repro.models.bert import BertConfig
    from repro.models.towers import make_bert_dual_encoder
    from repro.optim import chain, clip_by_global_norm, sgd

    quick = "--quick" in sys.argv
    D = 8
    assert jax.device_count() == D, jax.device_count()
    mesh = Mesh(np.array(jax.devices()), ("data",))
    shard_map, sm_kw = get_shard_map()

    B, K, QL, PL = 64, 2, 16, 32
    steps, warmup = (3, 1) if quick else (6, 2)
    bank = 1024 if quick else 4096

    bcfg = BertConfig(
        name="bench-bert", n_layers=2, d_model=64, n_heads=4, d_ff=128,
        vocab_size=2000, max_position=64, dtype=jnp.float32,
    )

    def make_batch(i):
        rng = np.random.default_rng(i)
        return RetrievalBatch(
            query=jnp.asarray(rng.integers(0, 2000, (B, QL), dtype=np.int32)),
            passage_pos=jnp.asarray(rng.integers(0, 2000, (B, PL), dtype=np.int32)),
            passage_hard=None,
        )

    def bench(precision, shard_banks):
        policy = resolve_precision(precision)
        cfg = ContrastiveConfig(
            method="contaccum", accumulation_steps=K, bank_size=bank,
            precision=policy, dp_axis=("data",), shard_banks=shard_banks,
        )
        enc = make_bert_dual_encoder(bcfg, precision=policy)
        tx = chain(clip_by_global_norm(2.0), sgd(0.05))
        state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
        spec = contrastive_state_spec(("data",), shard_banks)
        bspec = RetrievalBatch(query=P("data"), passage_pos=P("data"),
                               passage_hard=None)
        update = jax.jit(shard_map(
            build_step_program(enc, tx, cfg).update, mesh=mesh,
            in_specs=(spec, bspec), out_specs=(spec, P()), **sm_kw,
        ))
        for i in range(warmup):
            state, m = update(state, make_batch(i))
        jax.block_until_ready(m.loss)
        t0 = time.perf_counter()
        for i in range(warmup, warmup + steps):
            state, m = update(state, make_batch(i))
        jax.block_until_ready(m.loss)
        dt_ms = (time.perf_counter() - t0) / steps * 1e3
        assert np.isfinite(float(m.loss)), (precision, shard_banks)

        # persistent bank bytes: from the actual state (dtype included)
        assert state.bank_p.buf.dtype == policy.bank_dtype
        nq = state.bank_q.buf.shape[0]
        np_rows = state.bank_p.buf.shape[0]
        shards = D if shard_banks else 1
        bank_dev = bank_bytes_per_device(
            nq, np_rows, enc.rep_dim, policy, shards=shards
        )
        # compute-dtype representation bytes per loss evaluation: the local
        # chunk's rows + the assembled column block (gathered bank columns)
        c_item = jnp.dtype(policy.compute_dtype).itemsize
        rows = B // D // K + (nq // shards)
        cols = B // K + np_rows
        rep_dev = (rows + cols) * enc.rep_dim * c_item

        mode = "sharded" if shard_banks else "replicated"
        for metric, val in (
            ("bank_kib_per_dev", bank_dev / 1024.0),
            ("rep_kib_per_eval", rep_dev / 1024.0),
            ("step_ms", dt_ms),
        ):
            print(f"ROW precision/{precision}/{mode}/{metric} {val:.6g}",
                  flush=True)
        return bank_dev

    baseline = None
    for precision in ("fp32", "bf16", "bf16_banks"):
        for shard_banks in (False, True):
            bank_dev = bench(precision, shard_banks)
            if precision == "fp32" and not shard_banks:
                baseline = bank_dev
            else:
                red = 100.0 * (1.0 - bank_dev / baseline)
                mode = "sharded" if shard_banks else "replicated"
                print(f"ROW precision/{precision}/{mode}/"
                      f"bank_reduction_vs_fp32_pct {red:.6g}", flush=True)
    print("BENCH-DONE")
    """
)


def run(quick: bool = False) -> List[Tuple[str, float]]:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.pop("XLA_FLAGS", None)
    argv = [sys.executable, "-c", SCRIPT] + (["--quick"] if quick else [])
    proc = subprocess.run(
        argv,
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1800,
    )
    if proc.returncode != 0 or "BENCH-DONE" not in proc.stdout:
        raise RuntimeError(
            f"bench_precision subprocess failed:\n{proc.stdout}\n{proc.stderr}"
        )
    rows: List[Tuple[str, float]] = []
    print(f"{'cell':<58} {'value':>12}")
    for line in proc.stdout.splitlines():
        if not line.startswith("ROW "):
            continue
        _, name, value = line.split()
        rows.append((name, float(value)))
        print(f"{name:<58} {float(value):>12.4g}")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
