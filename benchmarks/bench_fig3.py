"""Paper Figure 3 (mechanism): memory-bank size x accumulation steps sweep.
Performance should improve with bank size and converge; ContAccum should
beat GradAccum at every total batch."""

from __future__ import annotations

from repro.core.types import ContrastiveConfig
from benchmarks.common import fmt_table, make_corpus, train_retriever

LOCAL, STEPS = 8, 120


def run(quick: bool = False):
    steps = 40 if quick else STEPS
    corpus = make_corpus(n=1024 if quick else 2048)
    banks = [0, 64, 256] if quick else [0, 64, 256, 1024]
    ks = [1, 4] if quick else [1, 4, 8]
    rows, out = [], []
    for k in ks:
        total = LOCAL * k
        for bank in banks:
            if bank == 0:
                cfg = ContrastiveConfig(method="grad_accum", accumulation_steps=k)
                name = f"grad_accum K={k}"
            else:
                cfg = ContrastiveConfig(
                    method="contaccum", accumulation_steps=k, bank_size=bank
                )
                name = f"contaccum K={k} mem={bank}"
            m = train_retriever(cfg, steps=steps, total_batch=total, corpus=corpus)
            rows.append((name, total, bank, f"{m['top@5']:.3f}", f"{m['top@20']:.3f}"))
            out.append((f"fig3/K{k}_mem{bank}/top@5", m["top@5"]))
    print("\n== Figure 3: bank size x accumulation steps ==")
    print(fmt_table(rows, ("setting", "N_total", "N_mem", "top@5", "top@20")))
    return out


if __name__ == "__main__":
    run()
