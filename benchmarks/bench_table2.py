"""Paper Table 2 (mechanism): component ablations of ContAccum.

  full            dual banks + past-encoder reps + GradAccum
  w/o M_q         passage-only bank (pre-batch negatives) -> gradient-norm
                  imbalance -> the paper's biggest drop
  w/o past enc    banks cleared at every update boundary
  w/o grad accum  K=1, dual banks only
"""

from __future__ import annotations

from repro.core.types import ContrastiveConfig
from benchmarks.common import fmt_table, make_corpus, train_retriever

TOTAL, LOCAL, BANK, STEPS = 64, 8, 256, 150
K = TOTAL // LOCAL


def run(quick: bool = False):
    steps = 40 if quick else STEPS
    corpus = make_corpus(n=1024 if quick else 2048)
    base = dict(accumulation_steps=K, bank_size=BANK)
    settings = [
        ("contaccum (full)", ContrastiveConfig(method="contaccum", **base)),
        ("w/o M_q", ContrastiveConfig(
            method="contaccum", use_query_bank=False, **base)),
        ("w/o past enc", ContrastiveConfig(
            method="contaccum", reset_banks_each_update=True, **base)),
        ("w/o grad accum", ContrastiveConfig(
            method="contaccum", accumulation_steps=1, bank_size=BANK)),
        ("w/o banks (=grad_accum)", ContrastiveConfig(
            method="grad_accum", accumulation_steps=K)),
    ]
    rows, out = [], []
    for name, cfg in settings:
        m = train_retriever(
            cfg, steps=steps, total_batch=TOTAL, corpus=corpus,
            track_ratio=True,
        )
        tail_ratio = sum(m["ratio_trace"][-20:]) / min(len(m["ratio_trace"]), 20)
        rows.append((
            name, f"{m['top@5']:.3f}", f"{m['top@20']:.3f}",
            f"{tail_ratio:.2f}",
        ))
        out.append((f"table2/{name}/top@5", m["top@5"]))
        out.append((f"table2/{name}/tail_grad_ratio", tail_ratio))
    print("\n== Table 2: ContAccum component ablations ==")
    print(fmt_table(rows, ("variant", "top@5", "top@20", "grad-ratio(tail)")))
    return out


if __name__ == "__main__":
    run()
