"""Paper Figure 5 + Appendix D (mechanism): GradNormRatio through training.

Dual banks keep ||grad_passage|| / ||grad_query|| ~= 1; a passage-only bank
(pre-batch negatives) drives it far above 1 — the gradient-norm imbalance
problem the paper identifies as the instability cause."""

from __future__ import annotations

import numpy as np

from repro.core.types import ContrastiveConfig
from benchmarks.common import fmt_table, make_corpus, train_retriever

TOTAL, LOCAL, BANK, STEPS = 64, 8, 256, 150
K = TOTAL // LOCAL


def run(quick: bool = False):
    steps = 60 if quick else STEPS
    corpus = make_corpus(n=1024 if quick else 2048)
    settings = [
        ("dpr (no banks)", ContrastiveConfig(method="dpr")),
        ("contaccum (dual)", ContrastiveConfig(
            method="contaccum", accumulation_steps=K, bank_size=BANK)),
        ("passage-only bank", ContrastiveConfig(
            method="contaccum", accumulation_steps=K, bank_size=BANK,
            use_query_bank=False)),
    ]
    rows, out = [], []
    for name, cfg in settings:
        m = train_retriever(
            cfg, steps=steps, total_batch=TOTAL, corpus=corpus,
            track_ratio=True,
        )
        tr = np.asarray(m["ratio_trace"])
        q = len(tr) // 4
        rows.append((
            name,
            f"{tr[:q].mean():.2f}", f"{tr[q:2*q].mean():.2f}",
            f"{tr[2*q:3*q].mean():.2f}", f"{tr[3*q:].mean():.2f}",
            f"{tr.max():.1f}",
        ))
        out.append((f"fig5/{name}/tail_ratio", float(tr[3*q:].mean())))
    print("\n== Figure 5: GradNormRatio (quartile means over training) ==")
    print(fmt_table(rows, ("setting", "q1", "q2", "q3", "q4", "max")))
    print(
        "reading: no-bank DPR stays ~1; passage-only diverges (the paper's\n"
        "imbalance claim). From-scratch towers at this lr put ANY bank past\n"
        "its staleness envelope, so the dual bank also drifts here — in the\n"
        "paper's slow-drift regime it stays ~1 (bench_regimes: 2.6 vs 2.8;\n"
        "tests/test_paper_claims.py pins dual < passage-only at matched\n"
        "settings)."
    )
    return out


if __name__ == "__main__":
    run()
