"""Shared benchmark substrate.

The paper's tables are reproduced at reduced scale on CPU with the synthetic
planted-relevance corpus (real NQ/TriviaQA/MS-Marco are not redistributable
offline — DESIGN.md §7.4). Every benchmark exercises the same production
code paths (core/step_program.py update programs, optim, data loaders); only
the encoder width and corpus size shrink.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.methods import build_step_program, init_state
from repro.core.types import ContrastiveConfig, RetrievalBatch
from repro.data.loader import ShardedLoader
from repro.data.retrieval import SyntheticRetrievalCorpus
from repro.models.bert import BertConfig
from repro.models.towers import make_bert_dual_encoder
from repro.optim.adamw import adamw, chain, clip_by_global_norm
from repro.optim.schedules import linear_warmup_linear_decay


def bench_bert(vocab: int = 2000, d: int = 64) -> BertConfig:
    return BertConfig(
        name="bench-bert",
        n_layers=2,
        d_model=d,
        n_heads=4,
        d_ff=2 * d,
        vocab_size=vocab,
        max_position=64,
        dtype=jnp.float32,
    )


def make_corpus(n: int = 2048, seed: int = 0) -> SyntheticRetrievalCorpus:
    return SyntheticRetrievalCorpus(
        n_passages=n, vocab_size=2000, q_len=16, p_len=32, n_hard=1, seed=seed
    )


def train_retriever(
    cfg: ContrastiveConfig,
    *,
    steps: int = 150,
    total_batch: int = 64,
    corpus: Optional[SyntheticRetrievalCorpus] = None,
    lr: float = 1e-3,
    seed: int = 0,
    use_hard: bool = True,
    track_ratio: bool = False,
) -> Dict:
    """Train a small BERT dual encoder with one of the paper's four methods;
    returns eval metrics (+ the GradNormRatio trace if requested)."""
    corpus = corpus or make_corpus()
    enc = make_bert_dual_encoder(bench_bert())
    tx = chain(
        clip_by_global_norm(cfg.grad_clip_norm),
        adamw(linear_warmup_linear_decay(lr, max(steps // 10, 1), steps)),
    )
    update = jax.jit(build_step_program(enc, tx, cfg).update, donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(seed), enc, tx, cfg)
    loader = ShardedLoader(corpus.n_passages, total_batch, seed=seed)

    ratios: List[float] = []
    losses: List[float] = []
    for step in range(steps):
        idx = loader.next_indices()
        b = corpus.batch(idx)
        batch = RetrievalBatch(
            query=jnp.asarray(b["query"]),
            passage_pos=jnp.asarray(b["passage_pos"]),
            passage_hard=jnp.asarray(b["passage_hard"]) if use_hard else None,
        )
        state, m = update(state, batch)
        if track_ratio:
            ratios.append(float(m.grad_norm_ratio))
        losses.append(float(m.loss))

    metrics = evaluate_topk(enc, state.params, corpus)
    metrics["final_loss"] = float(np.mean(losses[-10:]))
    if track_ratio:
        metrics["ratio_trace"] = ratios
    return metrics


from repro.evaluation import evaluate_topk  # re-export (public eval API)


def time_update(
    cfg: ContrastiveConfig,
    *,
    total_batch: int,
    n_timed: int = 3,
    seed: int = 0,
) -> float:
    """Median seconds per weight update (after compile warm-up)."""
    corpus = make_corpus(n=max(2 * total_batch, 512))
    enc = make_bert_dual_encoder(bench_bert())
    tx = chain(clip_by_global_norm(2.0), adamw(1e-4))
    update = jax.jit(build_step_program(enc, tx, cfg).update, donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(seed), enc, tx, cfg)
    idx = np.arange(total_batch)
    b = corpus.batch(idx)
    batch = RetrievalBatch(
        query=jnp.asarray(b["query"]),
        passage_pos=jnp.asarray(b["passage_pos"]),
        passage_hard=jnp.asarray(b["passage_hard"]),
    )
    state, m = update(state, batch)          # compile + warm
    jax.block_until_ready(m.loss)
    ts = []
    for _ in range(n_timed):
        t0 = time.perf_counter()
        state, m = update(state, batch)
        jax.block_until_ready(m.loss)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def fmt_table(rows: List[Tuple], headers: Tuple) -> str:
    widths = [
        max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
        for i, h in enumerate(headers)
    ]
    def line(vals):
        return "  ".join(str(v).ljust(w) for v, w in zip(vals, widths))
    sep = "  ".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])
