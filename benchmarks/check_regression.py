"""Compare a fresh BENCH_<suite>.json against its committed baseline.

The perf trajectory is tracked by checked-in baselines under
``benchmarks/baselines/`` (regenerate on the reference machine with
``PYTHONPATH=src python -m benchmarks.run --only <suite> --out-dir
benchmarks/baselines`` after an intentional perf change). CI runs the suite
and fails the build when:

  * a **time** row (name ending in ``_ms`` or ``_s``) regresses by more than
    ``--time-tol`` (default 15%), or
  * a **memory** row (name containing ``_kib``, ``_bytes`` or ``_mib``)
    regresses at all (beyond a 1% float/accounting epsilon) — compiled buffer
    sizes are deterministic, so any real growth is a change in the program.

Rows are matched by name; rows present on only one side are reported but
never fail the check (quick runs measure a subset of the full baseline).
Improvements are reported and always pass. Exit code 0 = clean, 1 =
regression, 2 = usage/IO error.

    python benchmarks/check_regression.py BENCH_distributed.json
    python benchmarks/check_regression.py out/BENCH_x.json baselines/BENCH_x.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

TIME_SUFFIXES = ("_ms", "_s")
MEMORY_MARKERS = ("_kib", "_bytes", "_mib")

DEFAULT_TIME_TOL = 0.15
MEMORY_EPS = 0.01


def row_kind(name: str) -> str:
    """'time' | 'memory' | 'info' — what regression rule a row falls under."""
    low = name.lower()
    if any(m in low for m in MEMORY_MARKERS):
        return "memory"
    if any(low.endswith(s) for s in TIME_SUFFIXES):
        return "time"
    return "info"


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: float(r["value"]) for r in payload.get("rows", [])}


def compare(current: dict, baseline: dict, time_tol: float = DEFAULT_TIME_TOL):
    """Returns (failures, lines): failure row names + a full report."""
    failures, lines = [], []
    for name in sorted(set(current) | set(baseline)):
        if name not in baseline:
            lines.append(f"  NEW      {name} = {current[name]:.6g} (no baseline)")
            continue
        if name not in current:
            lines.append(f"  MISSING  {name} (baseline {baseline[name]:.6g}; not measured)")
            continue
        cur, base = current[name], baseline[name]
        kind = row_kind(name)
        if base <= 0 or kind == "info":
            lines.append(f"  info     {name}: {base:.6g} -> {cur:.6g}")
            continue
        ratio = cur / base
        tol = time_tol if kind == "time" else MEMORY_EPS
        status = "ok"
        if ratio > 1.0 + tol:
            status = "FAIL"
            failures.append(name)
        elif ratio < 1.0:
            status = "better"
        lines.append(
            f"  {status:<8} {name}: {base:.6g} -> {cur:.6g} "
            f"({(ratio - 1.0) * 100:+.1f}%, {kind} tol {tol * 100:.0f}%)"
        )
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="freshly generated BENCH_<suite>.json")
    ap.add_argument(
        "baseline",
        nargs="?",
        default=None,
        help="committed baseline (default: benchmarks/baselines/<current basename>)",
    )
    ap.add_argument("--time-tol", type=float, default=DEFAULT_TIME_TOL,
                    help="relative step-time regression tolerance (default 0.15)")
    args = ap.parse_args(argv)

    baseline_path = args.baseline
    if baseline_path is None:
        baseline_path = os.path.join(
            os.path.dirname(os.path.abspath(__file__)),
            "baselines",
            os.path.basename(args.current),
        )
    for path in (args.current, baseline_path):
        if not os.path.exists(path):
            print(f"check_regression: no such file: {path}", file=sys.stderr)
            return 2

    failures, lines = compare(
        load_rows(args.current), load_rows(baseline_path), args.time_tol
    )
    print(f"check_regression: {args.current} vs {baseline_path}")
    print("\n".join(lines))
    if failures:
        print(f"\n{len(failures)} regression(s): " + ", ".join(failures))
        return 1
    print("\nno regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
