"""Replicated vs sharded memory banks, and all-gather vs ring loss comm,
under the shard_map StepProgram path.

Two measurements, both on forced host-platform devices:

  * **step sweep** (8 devices): per (method, bank, mode) — per-device bank
    bytes (replicated banks cost (N_q + N_p) * d * itemsize on EVERY chip, a
    sharded one 1/D of that) and mean step wall time. ``mode`` is
    ``replicated``, ``sharded`` (all-gather loss comm) or ``ring``
    (``loss_comm='ring'``: shards streamed around the DP ring).

  * **transient bytes** (D in {2, 4, 8}): compiled temp buffer bytes of one
    fused-backend loss evaluation (value_and_grad), via XLA's
    ``compile().memory_analysis()`` — the same inspection
    tests/test_hlo_analysis.py uses. This is the number the ring path
    exists to shrink: the all-gather path materializes the full
    (N_mem, d) passage-column block per eval (flat in D), the ring path
    peaks at one N_mem/D shard (~1/D scaling).

Runs in subprocesses because the forced device count must be set via
XLA_FLAGS before jax is first imported (benchmarks.run imports jax early),
mirroring the tests/test_distributed.py isolation pattern; the transient
sweep needs one subprocess per D.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from typing import List, Tuple

STEP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import time
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import ContrastiveConfig, RetrievalBatch, get_shard_map
    from repro.core.methods import build_step_program, init_state
    from repro.distribution.sharding import contrastive_state_spec
    from repro.models.bert import BertConfig
    from repro.models.towers import make_bert_dual_encoder
    from repro.optim import chain, clip_by_global_norm, sgd

    quick = "--quick" in sys.argv
    D = 8
    assert jax.device_count() == D, jax.device_count()
    mesh = Mesh(np.array(jax.devices()), ("data",))
    shard_map, sm_kw = get_shard_map()

    enc = make_bert_dual_encoder(BertConfig(
        name="bench-bert", n_layers=2, d_model=64, n_heads=4, d_ff=128,
        vocab_size=2000, max_position=64, dtype=jnp.float32,
    ))
    B, K, QL, PL = 64, 2, 16, 32
    # same timing window in quick mode: one warmup step still pays host
    # thread-pool/autotune amortization, inflating step_ms 2-8x vs the
    # committed baselines (quick saves by shrinking the method x bank
    # matrix instead, which is where the wall time actually goes)
    steps, warmup = 6, 2
    banks = [2048] if quick else [2048, 8192]

    def make_batch(i):
        rng = np.random.default_rng(i)
        return RetrievalBatch(
            query=jnp.asarray(rng.integers(0, 2000, (B, QL), dtype=np.int32)),
            passage_pos=jnp.asarray(rng.integers(0, 2000, (B, PL), dtype=np.int32)),
            passage_hard=None,
        )

    # mode -> (shard_banks, loss_comm)
    MODES = {
        "replicated": (False, "all_gather"),
        "sharded": (True, "all_gather"),
        "ring": (True, "ring"),
    }

    def bench(method, bank, mode):
        shard_banks, loss_comm = MODES[mode]
        cfg = ContrastiveConfig(
            method=method, accumulation_steps=K, bank_size=bank,
            dp_axis=("data",), shard_banks=shard_banks, loss_comm=loss_comm,
        )
        tx = chain(clip_by_global_norm(2.0), sgd(0.05))
        state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
        spec = contrastive_state_spec(("data",), shard_banks)
        bspec = RetrievalBatch(query=P("data"), passage_pos=P("data"),
                               passage_hard=None)
        update = jax.jit(shard_map(
            build_step_program(enc, tx, cfg).update, mesh=mesh,
            in_specs=(spec, bspec), out_specs=(spec, P()), **sm_kw,
        ))
        for i in range(warmup):
            state, m = update(state, make_batch(i))
        jax.block_until_ready(m.loss)
        t0 = time.perf_counter()
        for i in range(warmup, warmup + steps):
            state, m = update(state, make_batch(i))
        jax.block_until_ready(m.loss)
        dt_ms = (time.perf_counter() - t0) / steps * 1e3

        nq = state.bank_q.buf.shape[0]
        np_rows = state.bank_p.buf.shape[0]
        itemsize = jnp.dtype(cfg.resolved_bank_dtype()).itemsize
        per_dev = (nq + np_rows) * enc.rep_dim * itemsize
        if shard_banks:
            per_dev //= D
        print(f"ROW dist/{method}/bank{bank}/{mode}/bank_kib_per_dev "
              f"{per_dev / 1024.0:.6g}", flush=True)
        print(f"ROW dist/{method}/bank{bank}/{mode}/step_ms {dt_ms:.6g}",
              flush=True)

    for method in ("contaccum",) if quick else ("contaccum", "contcache"):
        for bank in banks:
            for mode in MODES:
                bench(method, bank, mode)
    print("BENCH-DONE")
    """
)

# One loss evaluation (fused backend, passage-bank columns only — isolating
# the column-communication path the two loss_comm modes differ in) lowered +
# compiled per mode: the per-device temp buffer bytes are read straight off
# XLA's memory analysis, no execution. ``base`` (no bank at all) bounds the
# bank-independent footprint so the bank-attributable transient is the
# difference. ``loss_fwd`` is the forward eval; ``loss_grad`` adds the VJP
# (whose ring bwd re-streams the shards instead of saving them).
TRANSIENT_SCRIPT = textwrap.dedent(
    """
    import os
    import sys
    D = int(sys.argv[1])
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={D}"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import get_shard_map
    from repro.core.dist import DistCtx
    from repro.core.loss import (
        FusedLossBackend, contrastive_loss, sharded_bank_extra_columns,
    )
    from repro.core.memory_bank import BankState

    N_MEM, REP_D, B_LOCAL = 2048, 64, 8
    assert jax.device_count() == D, jax.device_count()
    mesh = Mesh(np.array(jax.devices()), ("data",))
    shard_map, sm_kw = get_shard_map()
    ctx = DistCtx(("data",))
    backend = FusedLossBackend(interpret=True)

    B = B_LOCAL * D
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((B, REP_D)), jnp.float32)
    pp = jnp.asarray(rng.standard_normal((B, REP_D)), jnp.float32)
    pbuf = jnp.asarray(rng.standard_normal((N_MEM, REP_D)), jnp.float32)
    valid = jnp.ones((N_MEM,), bool)
    age = jnp.zeros((N_MEM,), jnp.int32)
    head = jnp.zeros((), jnp.int32)

    def make_eval(comm, grad):
        def eval_loss(q, pp, pbuf, valid, age, head):
            extra = None
            if comm is not None:
                bank_p = BankState(buf=pbuf, valid=valid, head=head, age=age)
                extra = sharded_bank_extra_columns(bank_p, ctx, comm)

            def f(q):
                loss, _ = contrastive_loss(
                    q, pp, extra_cols=extra,
                    temperature=0.5, ctx=ctx, backend=backend,
                )
                return loss

            if grad:
                return jax.value_and_grad(f)(q)
            return f(q), q

        row = P("data")
        return jax.jit(shard_map(
            eval_loss, mesh=mesh,
            in_specs=(row, row, row, row, row, P()),
            out_specs=(P(), row), **sm_kw,
        ))

    for grad in (False, True):
        stage = "loss_grad" if grad else "loss_fwd"
        for comm in (None, "all_gather", "ring"):
            compiled = make_eval(comm, grad).lower(
                q, pp, pbuf, valid, age, head
            ).compile()
            mem = compiled.memory_analysis()
            temp = getattr(mem, "temp_size_in_bytes", 0)
            name = comm or "base"
            print(f"ROW dist/transient/D{D}/{name}/{stage}_temp_kib "
                  f"{temp / 1024.0:.6g}", flush=True)
    print("BENCH-DONE")
    """
)

TRANSIENT_DS = (2, 4, 8)


def _subprocess_rows(argv, timeout=1200) -> List[Tuple[str, float]]:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        argv,
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=timeout,
    )
    if proc.returncode != 0 or "BENCH-DONE" not in proc.stdout:
        raise RuntimeError(
            f"bench_distributed subprocess failed:\n{proc.stdout}\n{proc.stderr}"
        )
    rows: List[Tuple[str, float]] = []
    for line in proc.stdout.splitlines():
        if not line.startswith("ROW "):
            continue
        _, name, value = line.split()
        rows.append((name, float(value)))
    return rows


def run(quick: bool = False) -> List[Tuple[str, float]]:
    rows = _subprocess_rows(
        [sys.executable, "-c", STEP_SCRIPT] + (["--quick"] if quick else [])
    )
    # the transient sweep is compile-only (cheap) and its 1/D scaling is the
    # headline number of the ring path, so it always covers every D
    for d in TRANSIENT_DS:
        rows += _subprocess_rows([sys.executable, "-c", TRANSIENT_SCRIPT, str(d)])
    print(f"{'cell':<48} {'value':>12}")
    for name, value in rows:
        print(f"{name:<48} {value:>12.4g}")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
