"""Replicated vs sharded memory banks under the shard_map StepProgram path.

Sweeps the dual-bank methods over bank depth on 8 forced host-platform
devices and reports, per (method, bank, mode):

  * per-device bank bytes — the memory the tentpole exists to cut: a
    replicated bank costs (N_q + N_p) * d * 4 bytes on EVERY chip, a sharded
    one 1/D of that;
  * mean step wall time — the price of the extra passage-bank column
    all-gather in sharded mode (on real interconnect this trades against the
    HBM freed; on host-platform CPU it is mostly a sanity signal).

Runs in a subprocess because the 8-device host platform must be forced via
XLA_FLAGS before jax is first imported (benchmarks.run imports jax early),
mirroring the tests/test_distributed.py isolation pattern.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from typing import List, Tuple

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import time
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core import ContrastiveConfig, RetrievalBatch, get_shard_map
    from repro.core.methods import build_step_program, init_state
    from repro.distribution.sharding import contrastive_state_spec
    from repro.models.bert import BertConfig
    from repro.models.towers import make_bert_dual_encoder
    from repro.optim import chain, clip_by_global_norm, sgd

    quick = "--quick" in sys.argv
    D = 8
    assert jax.device_count() == D, jax.device_count()
    mesh = Mesh(np.array(jax.devices()), ("data",))
    shard_map, sm_kw = get_shard_map()

    enc = make_bert_dual_encoder(BertConfig(
        name="bench-bert", n_layers=2, d_model=64, n_heads=4, d_ff=128,
        vocab_size=2000, max_position=64, dtype=jnp.float32,
    ))
    B, K, QL, PL = 64, 2, 16, 32
    steps, warmup = (3, 1) if quick else (6, 2)
    banks = [1024] if quick else [2048, 8192]

    def make_batch(i):
        rng = np.random.default_rng(i)
        return RetrievalBatch(
            query=jnp.asarray(rng.integers(0, 2000, (B, QL), dtype=np.int32)),
            passage_pos=jnp.asarray(rng.integers(0, 2000, (B, PL), dtype=np.int32)),
            passage_hard=None,
        )

    def bench(method, bank, shard_banks):
        cfg = ContrastiveConfig(
            method=method, accumulation_steps=K, bank_size=bank,
            dp_axis=("data",), shard_banks=shard_banks,
        )
        tx = chain(clip_by_global_norm(2.0), sgd(0.05))
        state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
        spec = contrastive_state_spec(("data",), shard_banks)
        bspec = RetrievalBatch(query=P("data"), passage_pos=P("data"),
                               passage_hard=None)
        update = jax.jit(shard_map(
            build_step_program(enc, tx, cfg).update, mesh=mesh,
            in_specs=(spec, bspec), out_specs=(spec, P()), **sm_kw,
        ))
        for i in range(warmup):
            state, m = update(state, make_batch(i))
        jax.block_until_ready(m.loss)
        t0 = time.perf_counter()
        for i in range(warmup, warmup + steps):
            state, m = update(state, make_batch(i))
        jax.block_until_ready(m.loss)
        dt_ms = (time.perf_counter() - t0) / steps * 1e3

        nq = state.bank_q.buf.shape[0]
        np_rows = state.bank_p.buf.shape[0]
        itemsize = jnp.dtype(cfg.resolved_bank_dtype()).itemsize
        per_dev = (nq + np_rows) * enc.rep_dim * itemsize
        if shard_banks:
            per_dev //= D
        mode = "sharded" if shard_banks else "replicated"
        print(f"ROW dist/{method}/bank{bank}/{mode}/bank_kib_per_dev "
              f"{per_dev / 1024.0:.6g}", flush=True)
        print(f"ROW dist/{method}/bank{bank}/{mode}/step_ms {dt_ms:.6g}",
              flush=True)

    for method in ("contaccum",) if quick else ("contaccum", "contcache"):
        for bank in banks:
            for shard_banks in (False, True):
                bench(method, bank, shard_banks)
    print("BENCH-DONE")
    """
)


def run(quick: bool = False) -> List[Tuple[str, float]]:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.pop("XLA_FLAGS", None)
    argv = [sys.executable, "-c", SCRIPT] + (["--quick"] if quick else [])
    proc = subprocess.run(
        argv,
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    if proc.returncode != 0 or "BENCH-DONE" not in proc.stdout:
        raise RuntimeError(
            f"bench_distributed subprocess failed:\n{proc.stdout}\n{proc.stderr}"
        )
    rows: List[Tuple[str, float]] = []
    print(f"{'cell':<48} {'value':>12}")
    for line in proc.stdout.splitlines():
        if not line.startswith("ROW "):
            continue
        _, name, value = line.split()
        rows.append((name, float(value)))
        print(f"{name:<48} {float(value):>12.4g}")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
