"""Benchmark harness: one module per paper table/figure + the roofline
report. Prints each table and a final ``name,value`` CSV, and writes one
machine-readable ``BENCH_<suite>.json`` artifact per suite (the perf
trajectory across PRs is reconstructed from these).

  PYTHONPATH=src python -m benchmarks.run           # full
  PYTHONPATH=src python -m benchmarks.run --quick   # reduced steps
  PYTHONPATH=src python -m benchmarks.run --only fig4
  PYTHONPATH=src python -m benchmarks.run --only precision --out-dir bench_out
"""

from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks import (
    bench_distributed,
    bench_fig3,
    bench_fig4,
    bench_fig5,
    bench_fused_infonce,
    bench_mining,
    bench_precision,
    bench_regimes,
    bench_roofline,
    bench_serving,
    bench_table1,
    bench_table2,
)

SUITES = {
    "table1": bench_table1.run,
    "table2": bench_table2.run,
    "fig3": bench_fig3.run,
    "fig4": bench_fig4.run,
    "fig5": bench_fig5.run,
    "regimes": bench_regimes.run,
    "roofline": bench_roofline.run,
    "fused_infonce": bench_fused_infonce.run,
    "distributed": bench_distributed.run,
    "mining": bench_mining.run,
    "precision": bench_precision.run,
    "serving": bench_serving.run,
}


def write_artifact(out_dir: str, suite: str, rows, elapsed_s: float, quick: bool) -> str:
    """One BENCH_<suite>.json per suite: everything a trend dashboard needs
    to diff runs — suite name, flags, wall time, and the (name, value) rows
    in run order."""
    payload = {
        "suite": suite,
        "quick": quick,
        "elapsed_s": round(elapsed_s, 3),
        "rows": [{"name": k, "value": v} for k, v in rows],
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, choices=sorted(SUITES))
    ap.add_argument("--out-dir", default=".",
                    help="where the BENCH_<suite>.json artifacts are written")
    args = ap.parse_args(argv)

    names = [args.only] if args.only else list(SUITES)
    all_rows = []
    for name in names:
        t0 = time.time()
        rows = SUITES[name](quick=args.quick) or []
        dt = time.time() - t0
        path = write_artifact(args.out_dir, name, rows, dt, args.quick)
        print(f"[{name}] done in {dt:.1f}s -> {path}")
        all_rows += rows

    print("\n== CSV ==")
    print("name,value")
    for k, v in all_rows:
        print(f"{k},{v:.6g}")


if __name__ == "__main__":
    main()
