"""Benchmark harness: one module per paper table/figure + the roofline
report. Prints each table and a final ``name,value`` CSV.

  PYTHONPATH=src python -m benchmarks.run           # full
  PYTHONPATH=src python -m benchmarks.run --quick   # reduced steps
  PYTHONPATH=src python -m benchmarks.run --only fig4
"""

from __future__ import annotations

import argparse
import time

from benchmarks import (
    bench_distributed,
    bench_fig3,
    bench_fig4,
    bench_fig5,
    bench_fused_infonce,
    bench_regimes,
    bench_roofline,
    bench_table1,
    bench_table2,
)

SUITES = {
    "table1": bench_table1.run,
    "table2": bench_table2.run,
    "fig3": bench_fig3.run,
    "fig4": bench_fig4.run,
    "fig5": bench_fig5.run,
    "regimes": bench_regimes.run,
    "roofline": bench_roofline.run,
    "fused_infonce": bench_fused_infonce.run,
    "distributed": bench_distributed.run,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, choices=sorted(SUITES))
    args = ap.parse_args(argv)

    names = [args.only] if args.only else list(SUITES)
    all_rows = []
    for name in names:
        t0 = time.time()
        rows = SUITES[name](quick=args.quick) or []
        print(f"[{name}] done in {time.time()-t0:.1f}s")
        all_rows += rows

    print("\n== CSV ==")
    print("name,value")
    for k, v in all_rows:
        print(f"{k},{v:.6g}")


if __name__ == "__main__":
    main()
