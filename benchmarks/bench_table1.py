"""Paper Table 1 (mechanism): the four methods under the same memory budget.

Low-resource budget = local batch 8; the total batch is 64 via K=8
accumulation. The high-resource reference (DPR, batch 64 in one pass) is the
bar ContAccum must beat from the low-resource setting — the paper's headline
claim. Reduced scale: 2-layer BERT towers, synthetic corpus, Top@k eval.
"""

from __future__ import annotations

from repro.core.types import ContrastiveConfig
from benchmarks.common import fmt_table, make_corpus, train_retriever

TOTAL, LOCAL, STEPS, BANK = 64, 8, 150, 256
K = TOTAL // LOCAL


def run(quick: bool = False):
    steps = 40 if quick else STEPS
    corpus = make_corpus(n=1024 if quick else 2048)
    settings = [
        ("dpr_low (BSZ=8)", ContrastiveConfig(method="dpr"), LOCAL),
        ("grad_accum", ContrastiveConfig(method="grad_accum", accumulation_steps=K), TOTAL),
        ("grad_cache", ContrastiveConfig(method="grad_cache", accumulation_steps=K), TOTAL),
        ("contaccum", ContrastiveConfig(
            method="contaccum", accumulation_steps=K, bank_size=BANK), TOTAL),
        ("dpr_high (BSZ=64)", ContrastiveConfig(method="dpr"), TOTAL),
    ]
    rows = []
    results = {}
    for name, cfg, batch in settings:
        m = train_retriever(cfg, steps=steps, total_batch=batch, corpus=corpus)
        results[name] = m
        rows.append((
            name, batch,
            f"{m['top@1']:.3f}", f"{m['top@5']:.3f}", f"{m['top@20']:.3f}",
            f"{m['final_loss']:.3f}",
        ))
    print("\n== Table 1: methods under a fixed memory budget ==")
    print(fmt_table(rows, ("method", "batch", "top@1", "top@5", "top@20", "loss")))
    ca, gc = results["contaccum"], results["grad_cache"]
    ga, lo = results["grad_accum"], results["dpr_low (BSZ=8)"]
    hi = results["dpr_high (BSZ=64)"]
    print(
        "reading: the negatives-count mechanism reproduces — "
        f"dpr_low({lo['top@5']:.3f}) << grad_accum({ga['top@5']:.3f}) < "
        f"grad_cache({gc['top@5']:.3f}) = dpr_high({hi['top@5']:.3f}) "
        "(grad_cache's full-batch-gradient identity holds exactly). "
        f"contaccum({ca['top@5']:.3f}) is outside its stability envelope "
        "from scratch at this lr — see bench_regimes for the warm-started "
        "comparison and EXPERIMENTS.md §Paper-validation."
    )
    return [
        (f"table1/{name}/top@5", results[name]["top@5"])
        for name, _, _ in settings
    ]


if __name__ == "__main__":
    run()
