"""Hard-negative mining benchmark (suite ``mining``).

Three questions about the repro/mining subsystem, answered with numbers:

  1. **Refresh cost** — wall time of one full refresh (corpus re-encode +
     top-k mining + teleportation filtering + table publish) as the corpus
     grows. The warm number is the steady-state cadence cost; the first
     refresh (compile included) is reported as an info row because compile
     time is environment noise.
  2. **Async vs blocking** — what the background pipeline buys: the median
     time the refresh hook holds the *training thread* in async mode
     (``hook_ms`` — a param snapshot + thread start, or a skip while one is
     in flight) vs the full blocking refresh a sync miner pays there
     (``refresh_block_ms`` — which includes draining the dispatched step
     queue before the snapshot, the honest cost of stopping training to
     mine), and how many training steps ran concurrently with the last
     async refresh (``steps_overlapped`` — the acceptance row: >= 1 means
     training really does overlap mining).
  3. **Does mining help?** — identical training budgets with in-batch
     negatives only vs with mined columns joined into every batch (sync
     refreshes, deterministic), then one exact recall@{1,10,100} eval per
     run. ``recall10_delta`` > 0 is the paper-facing claim: fresher, harder
     negatives beat in-batch sampling at equal step count.

The mined run follows the ANCE recipe this subsystem exists for — and the
teleportation knobs are load-bearing, not decorative: on this corpus
(256 passages, ~8 passages per topic) mining with ``depth_lo=1`` or with
``margin=0`` from a cold encoder *collapses* training (recall@10 drops to
~0.03 — every mined "negative" is a topic-mate the noisy query genuinely
matches, so the loss pushes queries out of their own topic cluster). A
warm-up before the first refresh, a band past the topic-mates
(``[8, 24)``) and a score margin make the same pipeline strictly beat the
in-batch baseline. Both failure and fix are the bench's point.

Time rows (``*_ms``) are regression-checked at the standard 15% tolerance;
recall and overlap rows are info rows (quality trends, not perf gates).
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_table
from repro.core.methods import build_step_program, init_state
from repro.core.types import ContrastiveConfig, RetrievalBatch
from repro.data.loader import MinedNegativeInjector, ShardedLoader
from repro.data.retrieval import SyntheticRetrievalCorpus
from repro.evaluation import evaluate_topk
from repro.launch.train import tiny_bert
from repro.mining import HardNegativeMiner, MinerConfig
from repro.models.towers import make_bert_dual_encoder
from repro.optim import adamw, chain, clip_by_global_norm

BATCH = 32


def _miner_cfg(sync: bool, refresh_every: int = 16,
               margin: float = 2.0) -> MinerConfig:
    # band [8, 24): past the corpus's ~8 topic-mates per passage; margin 2.0
    # additionally drops candidates the model can't yet separate from gold
    # (false-negative guard — see the module docstring for what happens
    # without these)
    return MinerConfig(
        refresh_every=refresh_every, top_k=24, n_negatives=4,
        depth_lo=8, depth_hi=24, margin=margin, sync=sync, query_batch=256,
    )


def _refresh_latency(enc, params, quick: bool):
    """Warm refresh wall time vs corpus size (one compiled shape each)."""
    out, table = [], []
    for n in ((256, 1024) if quick else (1024, 4096)):
        corpus = SyntheticRetrievalCorpus(n_passages=n, q_len=16, p_len=32)
        miner = HardNegativeMiner(
            enc, _miner_cfg(sync=True),
            queries=corpus.queries, passages=corpus.passages,
        )
        t0 = time.perf_counter()
        miner.refresh(params, 0)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        miner.refresh(params, 1)
        warm = time.perf_counter() - t0
        out += [
            (f"mining/refresh/np{n}/warm_ms", warm * 1e3),
            # compile-inclusive first refresh: info row (environment noise)
            (f"mining/refresh/np{n}/cold_over_warm", cold / warm),
        ]
        table.append((n, f"{warm * 1e3:.1f}", f"{cold * 1e3:.1f}"))
    print("\n== mining: refresh latency vs corpus size ==")
    print(fmt_table(table, ("n_passages", "warm_ms", "cold_ms")))
    return out


def _train(enc, corpus, steps: int, *, mined: bool, sync: bool,
           refresh_every: int, warmup: int = 0, seed: int = 0):
    """One fixed-budget training run; returns (final params, miner, ms spent
    inside each refresh-hook call on the training thread). ``warmup`` delays
    the first refresh (ANCE warm-up: mine only once the encoder is past its
    random phase); refreshes then fire every ``refresh_every`` steps."""
    cfg = ContrastiveConfig(
        method="dpr", negatives="mined" if mined else None, temperature=1.0
    )
    tx = chain(clip_by_global_norm(2.0), adamw(2e-3))
    update = jax.jit(build_step_program(enc, tx, cfg).update)
    state = init_state(jax.random.PRNGKey(seed), enc, tx, cfg)
    loader = ShardedLoader(corpus.n_passages, BATCH, seed=seed)

    miner = injector = None
    if mined:
        miner = HardNegativeMiner(
            enc, _miner_cfg(sync=sync, refresh_every=refresh_every),
            queries=corpus.queries, passages=corpus.passages,
        )
        injector = MinedNegativeInjector(
            miner.buffer.read, corpus.n_passages, seed=seed,
            state=loader.state, on_step=miner.note_step,
        )

    first = max(warmup, refresh_every)
    hook_ms = []
    for step in range(steps):
        idx = loader.next_indices()
        b = corpus.batch(idx)
        hard = b["passage_hard"]
        if injector is not None:
            ids = injector.mined_ids(idx, gold=idx, step=step)
            hard = np.concatenate([hard, corpus.passages[ids]], axis=1)
        state, _ = update(state, RetrievalBatch(
            query=jnp.asarray(b["query"]),
            passage_pos=jnp.asarray(b["passage_pos"]),
            passage_hard=jnp.asarray(hard),
        ))
        if (miner is not None and step + 1 >= first
                and (step + 1 - first) % refresh_every == 0):
            t0 = time.perf_counter()
            miner.refresh_hook(state, step)
            hook_ms.append((time.perf_counter() - t0) * 1e3)
    if miner is not None:
        miner.wait()  # drain (and surface) any in-flight refresh
    params = jax.device_get(state.params)
    return params, miner, hook_ms


def run(quick: bool = False) -> List[Tuple[str, float]]:
    enc = make_bert_dual_encoder(tiny_bert())
    params = enc.init(jax.random.PRNGKey(0))
    out = _refresh_latency(enc, params, quick)

    corpus = SyntheticRetrievalCorpus(n_passages=256, q_len=16, p_len=32)

    # async vs blocking: same budget, same cadence, opposite execution mode.
    # The median hook time keeps the first refresh's compile out of the
    # regression-gated number (it dominates the mean on a cold cache).
    _, m_async, kicks = _train(
        enc, corpus, 32, mined=True, sync=False, refresh_every=8
    )
    _, m_sync, blocks = _train(
        enc, corpus, 32, mined=True, sync=True, refresh_every=8
    )
    hook_ms = float(np.median(kicks))
    block_ms = float(np.median(blocks))
    out += [
        ("mining/async/hook_ms", hook_ms),
        ("mining/sync/refresh_block_ms", block_ms),
        # acceptance row: the last async refresh overlapped >= 1 train step
        ("mining/async/steps_overlapped", float(m_async.last_overlap)),
        ("mining/async/refreshes", float(m_async.refreshes)),
        ("mining/async/skipped", float(m_async.skipped)),
    ]
    print("\n== mining: async vs blocking refresh ==")
    print(fmt_table(
        [("async", f"{hook_ms:.1f}", str(m_async.last_overlap),
          str(m_async.refreshes)),
         ("sync", f"{block_ms:.1f}", "0", str(m_sync.refreshes))],
        ("mode", "train-thread ms/refresh (median)", "steps overlapped",
         "refreshes"),
    ))

    # mined vs in-batch at an identical step budget (sync = deterministic).
    # 96 steps regardless of --quick: the comparison is only meaningful once
    # the in-batch baseline itself has learned something to beat.
    steps, warmup, every = 96, 32, 16
    p_mined, _, _ = _train(
        enc, corpus, steps, mined=True, sync=True,
        refresh_every=every, warmup=warmup,
    )
    p_base, _, _ = _train(
        enc, corpus, steps, mined=False, sync=True, refresh_every=every
    )
    ks = (1, 10, 100)
    r_mined = evaluate_topk(enc, p_mined, corpus, ks=ks)
    r_base = evaluate_topk(enc, p_base, corpus, ks=ks)
    for k in ks:
        out += [
            (f"mining/recall{k}/in_batch", r_base[f"recall@{k}"]),
            (f"mining/recall{k}/mined", r_mined[f"recall@{k}"]),
        ]
    out.append((
        "mining/recall10_delta", r_mined["recall@10"] - r_base["recall@10"]
    ))
    print("\n== mining: mined vs in-batch negatives "
          f"({steps} steps, warm-up {warmup}, refresh every {every}) ==")
    print(fmt_table(
        [(f"recall@{k}", f"{r_base[f'recall@{k}']:.4f}",
          f"{r_mined[f'recall@{k}']:.4f}") for k in ks],
        ("cutoff", "in_batch", "mined"),
    ))
    return out


if __name__ == "__main__":
    import sys

    run(quick="--quick" in sys.argv)
