"""Regime analysis: where the paper's claims live at miniature scale.

The paper fine-tunes PRETRAINED BERT towers at lr 2e-5 for 10-100 epochs.
The mechanism benchmarks here train 2-layer towers from scratch for a few
hundred steps — a regime in which the memory bank's stop-gradient
representations are (a) initially noise and (b) stale relative to the
encoder's drift per update. This module measures the method ranking in two
regimes:

  * from-scratch @ lr 1e-3 — fast-drift regime: the bank is actively
    harmful (staleness >> signal), while the negatives-count mechanism
    (dpr_low << grad_accum < grad_cache = dpr_high) shows cleanly;
  * warm-started @ lr 1e-4 — a stand-in for the paper's pretrained
    encoder: all methods stable; ContAccum matches GradAccum and the
    bank's extra negatives are redundant against a 2048-passage corpus
    that in-batch negatives already cover.

The paper's *equations* are pinned exactly by tests/test_core_methods.py;
the dual-vs-passage-only gradient-balance claim is validated in the
controlled small-lr setting by tests/test_paper_claims.py and by
bench_fig5's passage-only divergence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_bert, fmt_table, make_corpus
from repro.core.methods import init_state, make_update_fn
from repro.core.types import ContrastiveConfig, RetrievalBatch
from repro.data.loader import ShardedLoader
from repro.evaluation import evaluate_topk
from repro.models.towers import make_bert_dual_encoder
from repro.optim.adamw import adamw, chain, clip_by_global_norm


def _train(enc, corpus, cfg, params0, steps, lr, seed=0):
    params0 = jax.tree_util.tree_map(jnp.copy, params0)
    tx = chain(clip_by_global_norm(2.0), adamw(lr))
    upd = jax.jit(make_update_fn(enc, tx, cfg), donate_argnums=(0,))
    st = init_state(jax.random.PRNGKey(seed), enc, tx, cfg, params=params0)
    loader = ShardedLoader(corpus.n_passages, 64, seed=seed)
    ratios = []
    for _ in range(steps):
        b = corpus.batch(loader.next_indices())
        st, m = upd(st, RetrievalBatch(
            jnp.asarray(b["query"]), jnp.asarray(b["passage_pos"]),
            jnp.asarray(b["passage_hard"]),
        ))
        ratios.append(float(m.grad_norm_ratio))
    tail = sum(ratios[-20:]) / min(len(ratios), 20)
    return st.params, evaluate_topk(enc, st.params, corpus), tail


def run(quick: bool = False):
    corpus = make_corpus(n=1024 if quick else 2048)
    enc = make_bert_dual_encoder(bench_bert())
    warm_steps = 60 if quick else 120
    steps = 80 if quick else 150

    # warm start once (in-batch negatives, the pretrained-encoder stand-in)
    p0 = enc.init(jax.random.PRNGKey(0))
    p_warm, m_warm, _ = _train(
        enc, corpus, ContrastiveConfig(method="dpr"), p0, warm_steps, 1e-3
    )

    settings = [
        ("grad_accum", ContrastiveConfig(method="grad_accum", accumulation_steps=8)),
        ("contaccum (dual bank)", ContrastiveConfig(
            method="contaccum", accumulation_steps=8, bank_size=256)),
        ("contaccum w/o M_q", ContrastiveConfig(
            method="contaccum", accumulation_steps=8, bank_size=256,
            use_query_bank=False)),
        ("dpr_high (BSZ=64)", ContrastiveConfig(method="dpr")),
    ]
    rows, out = [], []
    for name, cfg in settings:
        _, m, tail = _train(enc, corpus, cfg, p_warm, steps, 1e-4)
        rows.append((name, f"{m['top@5']:.3f}", f"{m['top@20']:.3f}", f"{tail:.2f}"))
        out.append((f"regimes/warm/{name}/top@5", m["top@5"]))
        out.append((f"regimes/warm/{name}/tail_ratio", tail))
    print("\n== Regime analysis: warm-started towers @ lr 1e-4 "
          f"(warm start itself: top@5 {m_warm['top@5']:.3f}) ==")
    print(fmt_table(rows, ("method", "top@5", "top@20", "grad-ratio(tail)")))
    print(
        "reading: all methods stable when the encoder moves slowly (the\n"
        "paper's pretrained/2e-5 regime); at this corpus scale the bank's\n"
        "extra negatives are redundant, so ContAccum tracks GradAccum —\n"
        "the paper's gains need corpora where N_total-1 in-batch negatives\n"
        "under-sample the space. From-scratch @ lr 1e-3 (bench_table1) is\n"
        "the opposite regime: representation drift makes any memory bank\n"
        "(dual or not) diverge, reproducing why prior work restricted\n"
        "pre-batch negatives to late epochs [paper §2.2 refs 37,38]."
    )
    return out


if __name__ == "__main__":
    run()
