"""Dense vs fused InfoNCE loss backend across bank sizes.

Measures what the fused kernel claims: wall time and the XLA temp-buffer
footprint of one ``value_and_grad`` through ``contrastive_loss`` as the
column count grows toward pod-scale bank depths (up to 128k columns in the
full sweep). The dense backend materializes the (M, N) logits block twice
(forward + backward); the fused backend streams (block_m x block_n) tiles.

On this CPU container the fused kernel runs in interpreter mode, so wall
time favors dense — the *memory* column is the load-bearing measurement
here (temp bytes scale O(M*N) dense vs O(M*block_n) fused); compiled-TPU
timing is what bench sizes the kernel for.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.loss import ExtraColumns, ExtraRows, contrastive_loss
from repro.core.loss import FusedLossBackend

ROWS = 128          # local batch rows (the paper's N_total)
DIM = 128           # representation dim (reduced-scale)
FUSED_BLOCK_N = 1024  # fewer grid steps than 128 at these widths


def _inputs(n_bank: int, seed: int = 0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (ROWS, DIM))
    pp = jax.random.normal(ks[1], (ROWS, DIM))
    bank_p = jax.random.normal(ks[2], (n_bank, DIM))
    bank_q = jax.random.normal(ks[3], (n_bank, DIM))
    # one warm-up stretch of invalid slots so the mask path is exercised
    valid = jnp.arange(n_bank) < (3 * n_bank // 4)
    extra_cols = ExtraColumns(reps=bank_p, valid=valid)
    extra_rows = ExtraRows(
        reps=bank_q,
        labels=jnp.arange(n_bank, dtype=jnp.int32),
        weight=valid.astype(jnp.float32),
    )
    return q, pp, extra_cols, extra_rows


def _bench(backend, n_bank: int, n_timed: int) -> Tuple[float, float]:
    """(median seconds, temp bytes) of value_and_grad(loss) wrt (q, p)."""
    q, pp, extra_cols, extra_rows = _inputs(n_bank)

    def loss(q_, pp_):
        l, _ = contrastive_loss(
            q_, pp_, extra_cols=extra_cols, extra_rows=extra_rows,
            backend=backend,
        )
        return l

    fn = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))
    try:
        mem = fn.lower(q, pp).compile().memory_analysis()
        temp_bytes = float(getattr(mem, "temp_size_in_bytes", 0) or 0)
    except Exception:
        temp_bytes = float("nan")
    (l, g) = fn(q, pp)
    jax.block_until_ready(l)
    ts = []
    for _ in range(n_timed):
        t0 = time.perf_counter()
        out = fn(q, pp)
        jax.block_until_ready(out[0])
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), temp_bytes


def run(quick: bool = False) -> List[Tuple[str, float]]:
    if quick:
        sizes = [512, 2048]
    elif jax.default_backend() == "tpu":
        sizes = [512, 2048, 8192, 32768, 131072]
    else:
        # interpret-mode fused at >=32k columns stalls a CPU box for minutes
        # per rep; the pod-scale points need the compiled kernel
        sizes = [512, 2048, 8192]
        print("[fused_infonce] no TPU: capping sweep at 8192 columns "
              "(32768/131072 need the compiled kernel)")
    n_timed = 2 if quick else 3
    rows: List[Tuple[str, float]] = []
    print("== fused InfoNCE backend sweep (cols = 2*B + bank) ==")
    print(f"{'bank':>8} {'impl':>6} {'ms/step':>10} {'temp MiB':>10}")
    for n_bank in sizes:
        for name, backend in (
            ("dense", None),
            ("fused", FusedLossBackend(block_n=FUSED_BLOCK_N)),
        ):
            t, b = _bench(backend, n_bank, n_timed)
            print(f"{n_bank:>8} {name:>6} {t * 1e3:>10.2f} {b / 2**20:>10.2f}")
            rows.append((f"fused_infonce/bank{n_bank}/{name}_ms", t * 1e3))
            rows.append((f"fused_infonce/bank{n_bank}/{name}_temp_mb", b / 2**20))
    return rows


if __name__ == "__main__":
    run(quick=True)
