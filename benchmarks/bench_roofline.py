"""Roofline report: render the per-(arch x shape x mesh) dry-run records
(experiments/dryrun/*.json) as the §Roofline table. Run the dry-run first:

  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import fmt_table

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(mesh: str = "single_pod_16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, mesh, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = False, mesh: str = "single_pod_16x16"):
    recs = load_records(mesh)
    if not recs:
        print(f"\n== Roofline: no dry-run records in {DRYRUN_DIR}/{mesh} — "
              "run repro.launch.dryrun first ==")
        return []
    rows, out = [], []
    for r in recs:
        cell = f"{r['arch']}/{r['shape']}"
        if not r.get("ok"):
            rows.append((cell, "FAIL", "", "", "", "", r.get("error", "")[:40]))
            continue
        rf = r["roofline"]
        t = max(rf["t_compute"], rf["t_memory"], rf["t_collective"])
        frac = rf["t_compute"] / t if t else 0.0
        rows.append((
            cell,
            f"{rf['t_compute']:.2e}",
            f"{rf['t_memory']:.2e}",
            f"{rf['t_collective']:.2e}",
            rf["dominant"],
            f"{frac:.3f}",
            f"{(r.get('useful_flops_ratio') or 0):.3f}",
        ))
        out.append((f"roofline/{cell}/compute_frac", frac))
    print(f"\n== Roofline terms per cell ({mesh}; seconds/step/device) ==")
    print(fmt_table(
        rows,
        ("cell", "t_compute", "t_memory", "t_collective", "dominant",
         "roofline_frac", "useful_flops"),
    ))
    return out


if __name__ == "__main__":
    run()
