"""Paper Figure 4 (mechanism): seconds per weight update vs total batch size.

The paper's speed claims, in order:
  grad_accum < contaccum << grad_cache
(GradCache pays an extra full forward; ContAccum only pays the enlarged
similarity matrix + bank bookkeeping.)"""

from __future__ import annotations

from repro.core.types import ContrastiveConfig
from benchmarks.common import fmt_table, time_update

LOCAL = 8


def run(quick: bool = False):
    totals = [32, 64] if quick else [32, 64, 128]
    bank = 256
    rows, out = [], []
    for total in totals:
        k = total // LOCAL
        t_ga = time_update(
            ContrastiveConfig(method="grad_accum", accumulation_steps=k),
            total_batch=total,
        )
        t_gc = time_update(
            ContrastiveConfig(method="grad_cache", accumulation_steps=k),
            total_batch=total,
        )
        t_ca = time_update(
            ContrastiveConfig(
                method="contaccum", accumulation_steps=k, bank_size=bank
            ),
            total_batch=total,
        )
        rows.append((
            total,
            f"{t_ga*1e3:.1f}", f"{t_gc*1e3:.1f}", f"{t_ca*1e3:.1f}",
            f"{t_gc/t_ga:.2f}x", f"{t_ca/t_ga:.2f}x",
        ))
        out += [
            (f"fig4/N{total}/grad_accum_ms", t_ga * 1e3),
            (f"fig4/N{total}/grad_cache_ms", t_gc * 1e3),
            (f"fig4/N{total}/contaccum_ms", t_ca * 1e3),
        ]
    print("\n== Figure 4: time per weight update (ms) ==")
    print(fmt_table(
        rows,
        ("N_total", "grad_accum", "grad_cache", "contaccum",
         "cache/accum", "cont/accum"),
    ))
    return out


if __name__ == "__main__":
    run()
