"""Serving sweep on the Retriever API (suite ``serving``).

For every (precision x index layout x search backend) point the harness
builds a Retriever on 8 forced host-platform devices, serves a fixed query
stream through the dynamic-batching server, and reports:

  * qps and p50/p99 request latency (submit -> result, measured at the
    future);
  * the coalesced-batch histogram (mean/max — the _collect fix means a
    backed-up queue fills batches instead of degrading to size 1);
  * persistent index bytes per device — the serving memory axis: bf16 index
    rows halve it, row-block sharding divides by D, composed: /(2·D).

Acceptance (ISSUE 5): sharded bf16 index bytes/device <= 12.5% of the
replicated fp32 baseline on 8 devices — the measured value is 6.25%
(bf16 halves, 8-way sharding divides by 8). Emitted as
``serving/<precision>/<layout>/index_reduction_vs_fp32_pct`` rows.

Runs in a subprocess because the 8-device host platform must be forced via
XLA_FLAGS before jax is first imported (same isolation pattern as
benchmarks/bench_precision.py).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap
from typing import List, Tuple

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import time
    import jax
    import numpy as np

    from repro.data.retrieval import SyntheticRetrievalCorpus
    from repro.launch.train import tiny_bert
    from repro.models.towers import make_bert_dual_encoder
    from repro.retrieval import (
        Retriever, RetrieverConfig, make_dp_mesh, make_server,
    )

    quick = "--quick" in sys.argv
    D = 8
    assert jax.device_count() == D, jax.device_count()
    mesh = make_dp_mesh(D)

    n_passages = 1024 if quick else 4096
    n_queries = 32 if quick else 96
    corpus = SyntheticRetrievalCorpus(n_passages=n_passages, q_len=16, p_len=32)

    def bench(precision, layout, impl):
        enc = make_bert_dual_encoder(tiny_bert(), precision=precision)
        params = enc.init(jax.random.PRNGKey(0))
        rcfg = RetrieverConfig(
            top_k=20, search_impl=impl, index_layout=layout,
            precision=precision, encode_batch=256,
            score_block=1024, block_n=256,
        )
        r = Retriever(enc, params, rcfg,
                      mesh=mesh if layout == "sharded" else None)
        store = r.build_index(corpus.passages)
        server = make_server(r, max_batch=16, max_wait_s=0.01).start()
        try:
            r.search(corpus.queries[:16])   # warm the compile cache
            lat = []
            t0 = time.perf_counter()
            futs = [
                (time.perf_counter(), server.submit(corpus.queries[i]))
                for i in range(n_queries)
            ]
            for t_sub, fut in futs:
                fut.get(timeout=120)
                lat.append(time.perf_counter() - t_sub)
            dt = time.perf_counter() - t0
        finally:
            server.stop()
        sizes = np.asarray(server.batch_sizes)
        cell = f"serving/{precision}/{layout}/{impl}"
        for metric, val in (
            ("qps", n_queries / dt),
            ("p50_ms", float(np.percentile(lat, 50)) * 1e3),
            ("p99_ms", float(np.percentile(lat, 99)) * 1e3),
            ("batch_mean", float(sizes.mean())),
            ("batch_max", float(sizes.max())),
            ("index_kib_per_dev", store.bytes_per_device() / 1024.0),
        ):
            print(f"ROW {cell}/{metric} {val:.6g}", flush=True)
        return store.bytes_per_device()

    baseline = None
    for precision in ("fp32", "bf16_banks"):
        for layout in ("replicated", "sharded"):
            for impl in ("dense", "fused"):
                idx_dev = bench(precision, layout, impl)
            # index bytes are impl-independent; report reduction per layout
            if precision == "fp32" and layout == "replicated":
                baseline = idx_dev
            else:
                red = 100.0 * (1.0 - idx_dev / baseline)
                print(f"ROW serving/{precision}/{layout}/"
                      f"index_reduction_vs_fp32_pct {red:.6g}", flush=True)
    print("BENCH-DONE")
    """
)


def run(quick: bool = False) -> List[Tuple[str, float]]:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.pop("XLA_FLAGS", None)
    argv = [sys.executable, "-c", SCRIPT] + (["--quick"] if quick else [])
    proc = subprocess.run(
        argv,
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=2400,
    )
    if proc.returncode != 0 or "BENCH-DONE" not in proc.stdout:
        raise RuntimeError(
            f"bench_serving subprocess failed:\n{proc.stdout}\n{proc.stderr}"
        )
    rows: List[Tuple[str, float]] = []
    print(f"{'cell':<58} {'value':>12}")
    for line in proc.stdout.splitlines():
        if not line.startswith("ROW "):
            continue
        _, name, value = line.split()
        rows.append((name, float(value)))
        print(f"{name:<58} {float(value):>12.4g}")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
