"""§Perf A3: the explicit-collective embedding lookup must match plain
jnp.take in value AND table gradient, on a real multi-device mesh."""

import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.models.recsys import make_psum_scatter_lookup

    assert jax.device_count() == 8
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))

    R, D, B, F = 64, 5, 16, 3
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(R, D)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, R, size=(B, F)).astype(np.int32))

    lookup = make_psum_scatter_lookup(
        mesh, table_axes=("model", "data"), batch_axes=("data", "model"))

    table_sh = jax.device_put(
        table, NamedSharding(mesh, P(("model", "data"), None)))
    idx_sh = jax.device_put(idx, NamedSharding(mesh, P(("data", "model"), None)))

    out = jax.jit(lookup)(table_sh, idx_sh)
    ref = jnp.take(table, idx, axis=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
    print("VALUE_OK")

    cot = jnp.asarray(rng.normal(size=(B, F, D)).astype(np.float32))

    def loss_new(t):
        return jnp.sum(lookup(t, idx_sh) * cot)

    def loss_ref(t):
        return jnp.sum(jnp.take(t, idx, axis=0) * cot)

    g_new = jax.jit(jax.grad(loss_new))(table_sh)
    g_ref = jax.grad(loss_ref)(table)
    np.testing.assert_allclose(np.asarray(g_new), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-6)
    print("GRAD_OK")
    """
)


@pytest.mark.slow
def test_psum_scatter_lookup_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "VALUE_OK" in res.stdout and "GRAD_OK" in res.stdout
