"""The batched-groups MoE (§Perf C1) must match the scan-over-groups
formulation exactly — same dispatch, same outputs, same aux losses."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import MoEConfig, init_moe, moe_ffn


def _setup(seed=0, t=64, d=16, e=8, k=2, f=32, g=16):
    cfg = MoEConfig(n_experts=e, top_k=k, d_expert=f, group_size=g,
                    capacity_factor=1.5)
    params = jax.tree_util.tree_map(
        lambda p: p[0],
        init_moe(jax.random.PRNGKey(seed), d, cfg, 1),
    )
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, d), jnp.float32)
    return cfg, params, x


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_vectorized_matches_scan(seed):
    cfg, params, x = _setup(seed)
    y_vec, m_vec = moe_ffn(params, x, dataclasses.replace(cfg, vectorize_groups=True))
    y_scan, m_scan = moe_ffn(params, x, dataclasses.replace(cfg, vectorize_groups=False))
    np.testing.assert_allclose(y_vec, y_scan, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(
        m_vec["moe_aux_loss"], m_scan["moe_aux_loss"], rtol=2e-5
    )
    np.testing.assert_allclose(
        m_vec["moe_dropped_frac"], m_scan["moe_dropped_frac"], rtol=2e-5, atol=1e-7
    )


def test_vectorized_grads_match_scan():
    cfg, params, x = _setup(3)

    def loss(params, x, vec):
        y, m = moe_ffn(params, x, dataclasses.replace(cfg, vectorize_groups=vec))
        return (y ** 2).sum() + m["moe_aux_loss"]

    gv = jax.grad(loss)(params, x, True)
    gs = jax.grad(loss)(params, x, False)
    for k in gv:
        np.testing.assert_allclose(gv[k], gs[k], rtol=5e-5, atol=1e-6,
                                   err_msg=k)


def test_capacity_drops_consistently():
    # tight capacity forces drops; both paths must drop the SAME tokens
    cfg, params, x = _setup(4)
    cfg = dataclasses.replace(cfg, capacity_factor=0.5)
    y_vec, m_vec = moe_ffn(params, x, dataclasses.replace(cfg, vectorize_groups=True))
    y_scan, m_scan = moe_ffn(params, x, dataclasses.replace(cfg, vectorize_groups=False))
    assert float(m_vec["moe_dropped_frac"]) > 0
    np.testing.assert_allclose(y_vec, y_scan, rtol=2e-5, atol=2e-6)
