"""FIFO ring-buffer semantics of the dual memory bank (paper Fig. 2)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memory_bank import clear, init_bank, n_valid, ordered, push, push_pair


def rows(vals, d=4):
    return jnp.stack([jnp.full((d,), v, jnp.float32) for v in vals])


def test_push_fills_then_wraps():
    bank = init_bank(4, 4)
    bank = push(bank, rows([1, 2]))
    assert int(n_valid(bank)) == 2
    bank = push(bank, rows([3, 4]))
    assert int(n_valid(bank)) == 4
    # wrap: 5 overwrites the oldest (1)
    bank = push(bank, rows([5]))
    buf, valid = ordered(bank)
    np.testing.assert_array_equal(np.asarray(buf[:, 0]), [2, 3, 4, 5])
    assert bool(valid.all())


def test_push_larger_than_capacity_keeps_newest():
    bank = init_bank(3, 4)
    bank = push(bank, rows([1, 2, 3, 4, 5]))
    vals = sorted(np.asarray(bank.buf[:, 0]).tolist())
    assert vals == [3, 4, 5]
    assert int(n_valid(bank)) == 3


def test_oversized_push_wraparound_is_last_write_wins():
    """Regression: when n > capacity the ring indices repeat, and a raw
    ``.at[idx].set`` scatter does not guarantee the later duplicate wins.
    push() must pre-slice to the final ``capacity`` rows: exact FIFO order,
    correct head, correct ages — including from a non-zero head."""
    # n = 2*cap + 1: every slot is hit >= 2 times
    bank = init_bank(3, 4)
    bank = push(bank, rows([1, 2]))          # head now 2
    bank = push(bank, rows([3, 4, 5, 6, 7, 8, 9]), step=7)
    buf, valid = ordered(bank)
    np.testing.assert_array_equal(np.asarray(buf[:, 0]), [7, 8, 9])
    assert bool(valid.all())
    # head advanced as if all 7 rows were enqueued one by one
    assert int(bank.head) == (2 + 7) % 3
    np.testing.assert_array_equal(np.asarray(bank.age), [7, 7, 7])
    # one more push lands after the newest retained row
    bank = push(bank, rows([10]))
    buf, _ = ordered(bank)
    np.testing.assert_array_equal(np.asarray(buf[:, 0]), [8, 9, 10])


def test_clear_invalidates():
    bank = init_bank(4, 4)
    bank = push(bank, rows([1, 2, 3]))
    bank = clear(bank)
    assert int(n_valid(bank)) == 0
    assert int(bank.head) == 0


def test_push_is_stop_gradient():
    """Bank entries must not carry gradients (paper's sg(.))."""

    def f(x):
        bank = init_bank(2, 4)
        bank = push(bank, x)
        return jnp.sum(bank.buf)

    g = jax.grad(f)(rows([1, 2]))
    np.testing.assert_array_equal(np.asarray(g), np.zeros((2, 4)))


def test_push_pair_alignment():
    bq = init_bank(4, 4)
    bp = init_bank(4, 4)
    for i in range(6):  # push in lockstep, wrap twice
        bq, bp = push_pair(bq, bp, rows([10 + i]), rows([20 + i]))
    # aligned slots: query 10+i sits at the same ring index as passage 20+i
    np.testing.assert_array_equal(
        np.asarray(bq.buf[:, 0]) + 10, np.asarray(bp.buf[:, 0])
    )


def test_zero_capacity_bank_noop():
    bank = init_bank(0, 4)
    bank2 = push(bank, rows([1, 2]))
    assert bank2.buf.shape == (0, 4)
    assert int(n_valid(bank2)) == 0


def test_jit_and_scan_compatible():
    bank = init_bank(8, 4)

    def body(bank, x):
        return push(bank, x[None, :]), None

    xs = jax.random.normal(jax.random.PRNGKey(0), (16, 4))
    bank, _ = jax.lax.scan(jax.jit(body), bank, xs)
    assert int(n_valid(bank)) == 8
    # the newest 8 rows survive
    got = np.sort(np.asarray(bank.buf), axis=0)
    want = np.sort(np.asarray(xs[8:]), axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-6)
