"""FIFO ring-buffer semantics of the dual memory bank (paper Fig. 2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.memory_bank import (
    aligned_valid,
    bank_spec,
    clear,
    init_bank,
    n_valid,
    ordered,
    push,
    push_pair,
    shard_push,
)


def rows(vals, d=4):
    return jnp.stack([jnp.full((d,), v, jnp.float32) for v in vals])


def test_push_fills_then_wraps():
    bank = init_bank(4, 4)
    bank = push(bank, rows([1, 2]))
    assert int(n_valid(bank)) == 2
    bank = push(bank, rows([3, 4]))
    assert int(n_valid(bank)) == 4
    # wrap: 5 overwrites the oldest (1)
    bank = push(bank, rows([5]))
    buf, valid = ordered(bank)
    np.testing.assert_array_equal(np.asarray(buf[:, 0]), [2, 3, 4, 5])
    assert bool(valid.all())


def test_push_larger_than_capacity_keeps_newest():
    bank = init_bank(3, 4)
    bank = push(bank, rows([1, 2, 3, 4, 5]))
    vals = sorted(np.asarray(bank.buf[:, 0]).tolist())
    assert vals == [3, 4, 5]
    assert int(n_valid(bank)) == 3


def test_oversized_push_wraparound_is_last_write_wins():
    """Regression: when n > capacity the ring indices repeat, and a raw
    ``.at[idx].set`` scatter does not guarantee the later duplicate wins.
    push() must pre-slice to the final ``capacity`` rows: exact FIFO order,
    correct head, correct ages — including from a non-zero head."""
    # n = 2*cap + 1: every slot is hit >= 2 times
    bank = init_bank(3, 4)
    bank = push(bank, rows([1, 2]))          # head now 2
    bank = push(bank, rows([3, 4, 5, 6, 7, 8, 9]), step=7)
    buf, valid = ordered(bank)
    np.testing.assert_array_equal(np.asarray(buf[:, 0]), [7, 8, 9])
    assert bool(valid.all())
    # head advanced as if all 7 rows were enqueued one by one
    assert int(bank.head) == (2 + 7) % 3
    np.testing.assert_array_equal(np.asarray(bank.age), [7, 7, 7])
    # one more push lands after the newest retained row
    bank = push(bank, rows([10]))
    buf, _ = ordered(bank)
    np.testing.assert_array_equal(np.asarray(buf[:, 0]), [8, 9, 10])


def test_clear_invalidates():
    bank = init_bank(4, 4)
    bank = push(bank, rows([1, 2, 3]))
    bank = clear(bank)
    assert int(n_valid(bank)) == 0
    assert int(bank.head) == 0


def test_push_is_stop_gradient():
    """Bank entries must not carry gradients (paper's sg(.))."""

    def f(x):
        bank = init_bank(2, 4)
        bank = push(bank, x)
        return jnp.sum(bank.buf)

    g = jax.grad(f)(rows([1, 2]))
    np.testing.assert_array_equal(np.asarray(g), np.zeros((2, 4)))


def test_push_pair_alignment():
    bq = init_bank(4, 4)
    bp = init_bank(4, 4)
    for i in range(6):  # push in lockstep, wrap twice
        bq, bp = push_pair(bq, bp, rows([10 + i]), rows([20 + i]))
    # aligned slots: query 10+i sits at the same ring index as passage 20+i
    np.testing.assert_array_equal(
        np.asarray(bq.buf[:, 0]) + 10, np.asarray(bp.buf[:, 0])
    )


def test_aligned_valid_rejects_unequal_nonzero_capacities():
    """Regression: with cq != cp (both > 0) the rings stay prefix-aligned
    only until either wraps — heads advance mod *different* capacities, so
    after capacity-lcm pushes slot i of M_q holds a query whose positive is
    NOT slot i of M_p. aligned_valid must refuse instead of silently
    mislabeling; only a disabled (capacity-0) bank is exempt."""
    bq, bp = init_bank(4, 4), init_bank(6, 4)
    # wrap BOTH rings (7 lockstep pushes > both capacities): the old prefix
    # assumption is now wrong for every slot, not just the tail
    for i in range(7):
        bq, bp = push_pair(bq, bp, rows([10 + i]), rows([20 + i]))
    with pytest.raises(ValueError, match="equal capacities"):
        aligned_valid(bq, bp)
    # disabled banks short-circuit to "no aligned rows"
    assert aligned_valid(init_bank(0, 4), bp).shape == (0,)
    assert not bool(aligned_valid(bq, init_bank(0, 4)).any())


def test_equal_capacity_alignment_survives_ring_wrap():
    """Positive control for the unequal-capacity rejection: equal-capacity
    lockstep rings keep slot i of M_q paired with slot i of M_p through
    multiple wraps."""
    bq, bp = init_bank(4, 4), init_bank(4, 4)
    for i in range(11):  # wraps the rings twice, ends mid-ring
        bq, bp = push_pair(bq, bp, rows([10 + i]), rows([20 + i]))
        filled = np.asarray(bq.valid)
        np.testing.assert_array_equal(
            np.asarray(bq.buf[filled, 0]) + 10, np.asarray(bp.buf[filled, 0])
        )
        assert bool(aligned_valid(bq, bp).all()) == (i >= 3)


def test_shard_push_union_matches_replicated_push():
    """Sharded banks are the replicated ring, partitioned: after any push
    sequence, concatenating the D shard-local banks (shard-major) must be
    bit-identical to the replicated bank, and every shard carries the same
    global head."""
    cap, n_shards, d = 12, 3, 4
    rng = np.random.default_rng(0)
    glob = init_bank(cap, d)
    shards = [init_bank(cap // n_shards, d) for _ in range(n_shards)]
    for step, n in enumerate([5, 3, 7, 4, 6]):  # wraps the ring repeatedly
        x = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        glob = push(glob, x, step)
        shards = [
            shard_push(s, x, step, shard_index=i, num_shards=n_shards)
            for i, s in enumerate(shards)
        ]
        for s in shards:
            assert int(s.head) == int(glob.head)

    def cat(field):
        return np.concatenate([np.asarray(getattr(s, field)) for s in shards])

    np.testing.assert_array_equal(cat("buf"), np.asarray(glob.buf))
    np.testing.assert_array_equal(cat("valid"), np.asarray(glob.valid))
    np.testing.assert_array_equal(cat("age"), np.asarray(glob.age))


def test_shard_push_oversized_keeps_newest_rows():
    """n > global capacity: last-write-wins pre-slicing works through the
    shard-local scatter exactly as it does for the replicated push."""
    cap, n_shards, d = 6, 2, 4
    glob = push(init_bank(cap, d), rows(list(range(1, 16)), d), step=3)
    shards = [
        shard_push(init_bank(cap // n_shards, d), rows(list(range(1, 16)), d),
                   step=3, shard_index=i, num_shards=n_shards)
        for i in range(n_shards)
    ]
    got = np.concatenate([np.asarray(s.buf) for s in shards])
    np.testing.assert_array_equal(got, np.asarray(glob.buf))
    assert all(int(s.head) == int(glob.head) for s in shards)


def test_bank_spec_shapes():
    from jax.sharding import PartitionSpec as P

    spec = bank_spec(("pod", "data"))
    assert spec.buf == P(("pod", "data"))
    assert spec.valid == P(("pod", "data")) and spec.age == P(("pod", "data"))
    assert spec.head == P()
    assert bank_spec(None).buf == P()
    assert bank_spec("data").buf == P("data")


def test_zero_capacity_bank_noop():
    bank = init_bank(0, 4)
    bank2 = push(bank, rows([1, 2]))
    assert bank2.buf.shape == (0, 4)
    assert int(n_valid(bank2)) == 0


def test_jit_and_scan_compatible():
    bank = init_bank(8, 4)

    def body(bank, x):
        return push(bank, x[None, :]), None

    xs = jax.random.normal(jax.random.PRNGKey(0), (16, 4))
    bank, _ = jax.lax.scan(jax.jit(body), bank, xs)
    assert int(n_valid(bank)) == 8
    # the newest 8 rows survive
    got = np.sort(np.asarray(bank.buf), axis=0)
    want = np.sort(np.asarray(xs[8:]), axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-6)
