"""The `StepProgram` composition API (core/step_program.py).

  * Exact gradient parity: the four legacy ``method=`` strings, resolved
    through the composed (negative source x backprop strategy) registry,
    must reproduce the seed monolithic implementations (tests/seed_methods.py)
    bit-for-bit-close over multi-step trajectories — with and without hard
    negatives and banks.
  * Registry: every advertised composition builds and jits.
  * New compositions: ``contcache`` (rep-cache x dual-bank) and
    ``prebatch_cache`` (rep-cache x passage-only-bank) train end-to-end and
    reduce to DPR when the banks are empty.
"""

import importlib.util
import os

import jax
import numpy as np
import pytest

from repro.core import (
    COMPOSITIONS,
    ContrastiveConfig,
    RetrievalBatch,
    available_methods,
    build_step_program,
    init_state,
    make_update_fn,
    method_composition,
)
from repro.optim import adamw, chain, clip_by_global_norm, sgd

from helpers import make_batch, make_mlp_encoder
from seed_methods import SEED_BUILDERS

LEGACY = ["dpr", "grad_accum", "grad_cache", "contaccum"]


def _tx(cfg: ContrastiveConfig):
    return chain(clip_by_global_norm(cfg.grad_clip_norm), sgd(0.1))


def _assert_state_close(sa, sb, msg, rtol=1e-6, atol=1e-8):
    for a, b in zip(jax.tree_util.tree_leaves(sa), jax.tree_util.tree_leaves(sb)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=rtol, atol=atol, err_msg=msg
        )


def _run_trajectory(update, state, batches):
    metrics = []
    for b in batches:
        state, m = update(state, b)
        metrics.append(m)
    return state, metrics


@pytest.mark.parametrize("method", LEGACY)
@pytest.mark.parametrize("n_hard", [0, 2])
def test_composed_program_matches_seed_implementation(method, n_hard):
    """3-step trajectories: params, banks and metrics must track the seed
    implementation exactly (same inputs, same optimizer)."""
    enc = make_mlp_encoder()
    kw = dict(accumulation_steps=1, bank_size=0)
    if method in ("grad_accum", "grad_cache"):
        kw = dict(accumulation_steps=4, bank_size=0)
    if method == "contaccum":
        kw = dict(accumulation_steps=4, bank_size=12)
    cfg = ContrastiveConfig(method=method, **kw)
    tx = _tx(cfg)

    batches = [make_batch(jax.random.PRNGKey(100 + i), 16, n_hard=n_hard) for i in range(3)]

    state0 = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    seed_update = jax.jit(SEED_BUILDERS[method](enc, tx, cfg))
    new_update = jax.jit(build_step_program(enc, tx, cfg).update)

    s_seed, m_seed = _run_trajectory(seed_update, state0, batches)
    s_new, m_new = _run_trajectory(new_update, state0, batches)

    _assert_state_close(s_seed.params, s_new.params, f"{method}: params diverge")
    _assert_state_close(s_seed.opt_state, s_new.opt_state, f"{method}: opt state")
    for bank in ("bank_q", "bank_p"):
        _assert_state_close(
            getattr(s_seed, bank), getattr(s_new, bank), f"{method}: {bank}"
        )
    # contaccum's reported loss/accuracy intentionally diverge from the seed:
    # the seed averaged per-chunk means unweighted, mis-weighting warm-up
    # chunks whose extra-row counts differ; the program weights by n_rows
    # (test_scanned_metrics_are_row_weighted pins the fixed value). Gradients,
    # params and banks remain exact.
    fields = ("loss", "accuracy", "grad_norm", "grad_norm_ratio",
              "n_negatives", "bank_fill_q", "bank_fill_p")
    if method == "contaccum":
        fields = tuple(f for f in fields if f not in ("loss", "accuracy"))
    for ms, mn in zip(m_seed, m_new):
        for field in fields:
            np.testing.assert_allclose(
                float(getattr(ms, field)), float(getattr(mn, field)),
                rtol=1e-5, err_msg=f"{method}: metric {field}",
            )


@pytest.mark.parametrize("method", ["contaccum"])
def test_parity_under_ablation_flags(method):
    """Seed parity also holds for the bank ablations (reset-each-update /
    passage-only via use_query_bank=False)."""
    enc = make_mlp_encoder()
    for flags in (dict(reset_banks_each_update=True), dict(use_query_bank=False)):
        cfg = ContrastiveConfig(
            method=method, accumulation_steps=2, bank_size=8, **flags
        )
        tx = _tx(cfg)
        state0 = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
        batches = [make_batch(jax.random.PRNGKey(i), 8) for i in range(3)]
        s_seed, _ = _run_trajectory(jax.jit(SEED_BUILDERS[method](enc, tx, cfg)), state0, batches)
        s_new, _ = _run_trajectory(jax.jit(build_step_program(enc, tx, cfg).update), state0, batches)
        _assert_state_close(s_seed.params, s_new.params, f"{flags}: params")
        _assert_state_close(s_seed.bank_p, s_new.bank_p, f"{flags}: bank_p")


def test_scanned_metrics_are_row_weighted():
    """Regression: _reduce_scanned_aux must weight per-chunk loss/accuracy by
    each chunk's row count. During bank warm-up the chunks see different
    numbers of valid extra rows (chunk 0: none; chunk 1: the rows chunk 0
    pushed), so the unweighted mean of chunk means is NOT the mean over the
    update's rows — the fixed metric must match a hand-computed reference."""
    from repro.core import contrastive_step_loss, init_bank, push_pair

    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(method="contaccum", accumulation_steps=2, bank_size=4)
    tx = _tx(cfg)
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    batch = make_batch(jax.random.PRNGKey(7), 8)
    _, m = jax.jit(build_step_program(enc, tx, cfg).update)(state, batch)

    # hand-computed reference: replay the two chunk evaluations + pushes
    q = enc.encode_query(state.params, batch.query)
    p = enc.encode_passage(state.params, batch.passage_pos)
    bq, bp = init_bank(4, enc.rep_dim), init_bank(4, enc.rep_dim)
    losses, accs, ns = [], [], []
    for k in range(2):
        qk, pk = q[4 * k : 4 * (k + 1)], p[4 * k : 4 * (k + 1)]
        _, aux = contrastive_step_loss(qk, pk, None, bq, bp)
        losses.append(float(aux.loss))
        accs.append(float(aux.accuracy))
        ns.append(float(aux.n_rows))
        bq, bp = push_pair(bq, bp, qk, pk)
    assert ns == [4.0, 8.0]  # warm-up: chunk 1 gained 4 aligned bank rows
    want_loss = sum(l * n for l, n in zip(losses, ns)) / sum(ns)
    want_acc = sum(a * n for a, n in zip(accs, ns)) / sum(ns)
    # the old unweighted mean of chunk means is a genuinely different number
    assert abs(want_loss - np.mean(losses)) > 1e-6
    np.testing.assert_allclose(float(m.loss), want_loss, rtol=1e-6)
    np.testing.assert_allclose(float(m.accuracy), want_acc, rtol=1e-6)


def test_unequal_nonzero_dual_bank_capacities_rejected():
    """Regression: bank_size_q != bank_size_p (both > 0) silently corrupted
    extra-row labels once either ring wrapped (heads advance mod different
    capacities). The dual-bank source must refuse to build such a config;
    disabling one bank entirely (the pre-batch ablation) stays allowed."""
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(
        method="contaccum", accumulation_steps=2, bank_size_q=4, bank_size_p=6
    )
    with pytest.raises(ValueError, match="equal non-zero capacities"):
        build_step_program(enc, _tx(cfg), cfg)
    # zero-capacity query bank (pre-batch shape) still builds
    ok = ContrastiveConfig(
        method="contaccum", accumulation_steps=2, bank_size=6, use_query_bank=False
    )
    build_step_program(enc, _tx(ok), ok)


def test_shard_banks_requires_dp_axis():
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(
        method="contaccum", accumulation_steps=2, bank_size=8, shard_banks=True
    )
    with pytest.raises(ValueError, match="shard_banks"):
        build_step_program(enc, _tx(cfg), cfg)


def test_loss_comm_validated_at_build():
    enc = make_mlp_encoder()
    base = dict(method="contaccum", accumulation_steps=2, bank_size=8)
    cfg = ContrastiveConfig(**base, loss_comm="carrier_pigeon")
    with pytest.raises(ValueError, match="unknown loss_comm"):
        build_step_program(enc, _tx(cfg), cfg)
    # ring streams bank shards — meaningless without sharded banks ...
    cfg = ContrastiveConfig(**base, dp_axis="dp", loss_comm="ring")
    with pytest.raises(ValueError, match="loss_comm"):
        build_step_program(enc, _tx(cfg), cfg)
    # ... or without banks at all
    cfg = ContrastiveConfig(method="dpr", dp_axis="dp", loss_comm="ring")
    with pytest.raises(ValueError, match="loss_comm"):
        build_step_program(enc, _tx(cfg), cfg)


def test_every_advertised_composition_builds_and_jits():
    enc = make_mlp_encoder()
    batch = make_batch(jax.random.PRNGKey(5), 8, n_hard=1)
    for method in available_methods():
        neg, bp = method_composition(method)
        cfg = ContrastiveConfig(
            method=method,
            accumulation_steps=2 if bp != "direct" else 1,
            bank_size=8 if neg in ("dual_bank", "passage_bank") else 0,
            dp_axis="dp" if neg == "gathered" else None,
        )
        tx = _tx(cfg)
        program = build_step_program(enc, tx, cfg)
        assert program.name == method
        assert program.source.name == neg and program.strategy.name == bp
        state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
        if neg == "gathered":
            from jax.sharding import Mesh, PartitionSpec as P

            from helpers import get_shard_map

            shard_map, sm_kw = get_shard_map()
            mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
            spec = RetrievalBatch(query=P("dp"), passage_pos=P("dp"),
                                  passage_hard=P("dp"))
            update = jax.jit(shard_map(
                program.update, mesh=mesh, in_specs=(P(), spec),
                out_specs=(P(), P()), **sm_kw,
            ))
        else:
            update = jax.jit(program.update)
        state, m = update(state, batch)
        assert np.isfinite(float(m.loss)), method
        for leaf in jax.tree_util.tree_leaves(state.params):
            assert np.all(np.isfinite(np.asarray(leaf))), method


def test_explicit_axes_override_method_string():
    """negatives=/backprop= fields compose freely and win over method=."""
    enc = make_mlp_encoder()
    batch = make_batch(jax.random.PRNGKey(9), 8, n_hard=1)
    # dpr + backprop=rep_cache is grad_cache
    cfg_a = ContrastiveConfig(method="dpr", backprop="rep_cache", accumulation_steps=2)
    cfg_b = ContrastiveConfig(method="grad_cache", accumulation_steps=2)
    tx = _tx(cfg_a)
    state0 = init_state(jax.random.PRNGKey(0), enc, tx, cfg_a)
    s_a, _ = jax.jit(build_step_program(enc, tx, cfg_a).update)(state0, batch)
    s_b, _ = jax.jit(build_step_program(enc, tx, cfg_b).update)(state0, batch)
    _assert_state_close(s_a.params, s_b.params, "override != grad_cache")
    assert build_step_program(enc, tx, cfg_a).name == "grad_cache"


def test_unknown_names_raise():
    enc = make_mlp_encoder()
    tx = _tx(ContrastiveConfig())
    with pytest.raises(ValueError, match="unknown method"):
        build_step_program(enc, tx, ContrastiveConfig(method="nope"))
    with pytest.raises(ValueError, match="unknown negatives"):
        build_step_program(enc, tx, ContrastiveConfig(negatives="nope", backprop="scan"))
    with pytest.raises(ValueError, match="unknown backprop"):
        build_step_program(enc, tx, ContrastiveConfig(negatives="in_batch", backprop="nope"))
    with pytest.raises(ValueError, match="dp_axis"):
        build_step_program(enc, tx, ContrastiveConfig(method="dpr_xdev"))


@pytest.mark.parametrize("method", ["contcache", "prebatch_cache"])
def test_cache_compositions_reduce_to_dpr_with_empty_banks(method):
    """rep-cache backprop is exact: with no bank entries both new cache
    compositions must produce DPR's full-batch gradients."""
    enc = make_mlp_encoder()
    batch = make_batch(jax.random.PRNGKey(4), 16, n_hard=1)
    cfg_dpr = ContrastiveConfig(method="dpr")
    cfg_new = ContrastiveConfig(method=method, accumulation_steps=4, bank_size=0)
    tx = _tx(cfg_dpr)
    s0 = init_state(jax.random.PRNGKey(0), enc, tx, cfg_dpr)
    s_dpr, m_dpr = jax.jit(build_step_program(enc, tx, cfg_dpr).update)(s0, batch)
    s0n = init_state(jax.random.PRNGKey(0), enc, _tx(cfg_new), cfg_new)
    s_new, m_new = jax.jit(build_step_program(enc, _tx(cfg_new), cfg_new).update)(s0n, batch)
    np.testing.assert_allclose(float(m_dpr.loss), float(m_new.loss), rtol=1e-6)
    _assert_state_close(s_dpr.params, s_new.params, method, rtol=2e-5, atol=1e-7)


def test_contcache_trains_with_bank_extended_negatives():
    """contcache: full-batch loss (rep-cache) + dual banks. After warm-up the
    negative count exceeds the in-batch total, banks stay in lockstep, and
    the loss is finite over a short training run."""
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(method="contcache", accumulation_steps=4, bank_size=32)
    tx = chain(clip_by_global_norm(2.0), adamw(1e-2))
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    update = jax.jit(build_step_program(enc, tx, cfg).update)
    for i in range(4):
        state, m = update(state, make_batch(jax.random.PRNGKey(20 + i), 16))
    # one full-batch loss per update: columns = B + N_mem -> 16 + 32 - 1
    assert float(m.n_negatives) == 16 + 32 - 1
    assert float(m.bank_fill_q) == 32.0 and float(m.bank_fill_p) == 32.0
    assert np.isfinite(float(m.loss))


def test_prebatch_cache_has_no_query_bank():
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(method="prebatch_cache", accumulation_steps=2, bank_size=16)
    tx = _tx(cfg)
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    assert state.bank_q.buf.shape[0] == 0        # passage-only source
    assert state.bank_p.buf.shape[0] == 16
    update = jax.jit(build_step_program(enc, tx, cfg).update)
    for i in range(3):
        state, m = update(state, make_batch(jax.random.PRNGKey(i), 8))
    assert float(m.bank_fill_p) == 16.0
    assert float(m.bank_fill_q) == 0.0
    assert float(m.n_negatives) == 8 + 16 - 1    # full batch + passage bank


def test_make_update_fn_is_thin_registry_over_programs():
    """The legacy factory and the program builder return the same update."""
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(method="contaccum", accumulation_steps=2, bank_size=8)
    tx = _tx(cfg)
    batch = make_batch(jax.random.PRNGKey(3), 8)
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    s_a, m_a = jax.jit(make_update_fn(enc, tx, cfg))(state, batch)
    s_b, m_b = jax.jit(build_step_program(enc, tx, cfg).update)(state, batch)
    np.testing.assert_allclose(float(m_a.loss), float(m_b.loss), rtol=0)
    _assert_state_close(s_a.params, s_b.params, "factory != program")


def test_registry_covers_full_matrix_of_shipped_methods():
    """Every (source, strategy) pair the paper + the new methods need is an
    advertised composition; names resolve both ways."""
    cells = {method_composition(m) for m in available_methods()}
    for want in [
        ("in_batch", "direct"), ("in_batch", "scan"), ("in_batch", "rep_cache"),
        ("dual_bank", "scan"), ("dual_bank", "rep_cache"),
        ("passage_bank", "scan"), ("passage_bank", "rep_cache"),
        ("gathered", "direct"),
    ]:
        assert want in cells, want
    assert COMPOSITIONS["contaccum"] == ("dual_bank", "scan")


# ------------------------------------------------------------------ drivers
def _load_example(name):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "examples", f"{name}.py")
    spec = importlib.util.spec_from_file_location(f"example_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.mark.parametrize("method", ["contcache", "prebatch_cache"])
def test_new_methods_train_end_to_end_through_example_driver(method):
    """examples/train_retriever.py drives the new compositions unchanged."""
    mod = _load_example("train_retriever")
    mod.main([
        "--method", method,
        "--steps", "3",
        "--warmup-steps", "2",
        "--total-batch", "16",
        "--local-batch", "8",
        "--bank", "16",
        "--corpus", "64",
    ])


def test_contrastive_cell_serves_new_compositions():
    """launch/steps.py builds the contrastive cell for the new methods; the
    program traces with the cell's sharded abstract inputs."""
    from jax.sharding import Mesh

    from repro.launch.steps import build_cell

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    for shape in ("contcache_batch", "prebatch_cache_batch"):
        prog = build_cell("dpr-bert-base", shape, mesh)
        assert prog.static_info["method"] == shape.replace("_batch", "")
        out = jax.eval_shape(prog.fn, *prog.args)
        assert out is not None
    # the shard_map (xdev) cells trace with sharded-bank state specs too
    for shape in ("contaccum_xdev", "contcache_xdev"):
        prog = build_cell("dpr-bert-base", shape, mesh)
        assert prog.static_info["method"] == shape.replace("_xdev", "")
        assert prog.static_info["xdev"] and prog.static_info["shard_banks"]
        out = jax.eval_shape(prog.fn, *prog.args)
        assert out is not None
