"""The loop-aware HLO cost parser is the source of every §Roofline number —
validate it against analytically-known programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, roofline


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 256), jnp.float32)

    text = _compiled_text(lambda x, y: x @ y, a, b)
    stats = analyze_hlo(text, 1)
    assert stats.n_dots == 1
    assert stats.flops == pytest.approx(2 * 64 * 128 * 256, rel=1e-6)


def test_scan_of_matmuls_multiplies_by_trip_count():
    trips = 7
    a = jax.ShapeDtypeStruct((trips, 32, 32), jnp.float32)

    def fn(ms):
        def body(x, m):
            return jnp.tanh(x @ m), None

        out, _ = jax.lax.scan(body, jnp.eye(32), ms)
        return out

    text = _compiled_text(fn, a)
    stats = analyze_hlo(text, 1)
    expected = trips * 2 * 32 * 32 * 32
    # XLA may unroll small loops (then dots appear `trips` times at mult 1);
    # either way the loop-corrected total must match the analytic count.
    assert stats.flops == pytest.approx(expected, rel=1e-6)


def test_nested_scan_multiplies_both_trip_counts():
    outer, inner = 5, 3
    a = jax.ShapeDtypeStruct((outer, inner, 16, 16), jnp.float32)

    def fn(ms):
        def inner_body(x, m):
            return x @ m, None

        def outer_body(x, mm):
            y, _ = jax.lax.scan(inner_body, x, mm)
            return y, None

        out, _ = jax.lax.scan(outer_body, jnp.eye(16), ms)
        return out

    text = _compiled_text(fn, a)
    stats = analyze_hlo(text, 1)
    expected = outer * inner * 2 * 16 * 16 * 16
    assert stats.flops == pytest.approx(expected, rel=1e-6)


def test_grad_of_scan_counts_fwd_and_bwd():
    trips = 6
    a = jax.ShapeDtypeStruct((trips, 24, 24), jnp.float32)

    def loss(ms):
        def body(x, m):
            return x @ m, None

        out, _ = jax.lax.scan(body, jnp.ones((24, 24)), ms)
        return out.sum()

    text = _compiled_text(jax.grad(loss), a)
    stats = analyze_hlo(text, 1)
    # fwd recompute (residual stashing) = trips dots; bwd = 2 dots per step
    # (dx and dm). Depending on what XLA simplifies, expect in [2, 3]x.
    base = trips * 2 * 24 * 24 * 24
    assert base * 1.9 <= stats.flops <= base * 3.1


def test_hbm_bytes_single_fusion_scale():
    n = 1 << 20
    a = jax.ShapeDtypeStruct((n,), jnp.float32)
    text = _compiled_text(lambda x: jnp.tanh(x) * 2.0 + 1.0, a)
    stats = analyze_hlo(text, 1)
    # one fused elementwise pass: read n*4, write n*4 (allow copies margin)
    assert 2 * n * 4 <= stats.hbm_bytes <= 6 * n * 4


def test_roofline_dominance():
    # pure compute program -> compute-dominant at these shapes
    a = jax.ShapeDtypeStruct((2048, 2048), jnp.bfloat16)
    text = _compiled_text(lambda x: x @ x, a)
    stats = analyze_hlo(text, 1)
    r = roofline(stats)
    assert r.t_compute > 0 and r.t_memory > 0
    assert r.dominant in ("compute", "memory", "collective")
    assert r.flops == stats.flops


def test_collective_parse_from_sharded_program():
    if jax.device_count() < 4:
        pytest.skip("needs forced multi-device host")


def test_unannotated_loop_counter_type():
    a = jax.ShapeDtypeStruct((4, 8, 8), jnp.float32)

    def fn(ms):
        def body(x, m):
            return x @ m, None

        out, _ = jax.lax.scan(body, jnp.eye(8), ms)
        return out

    stats = analyze_hlo(_compiled_text(fn, a), 1)
    assert stats.n_unannotated_loops >= 0
