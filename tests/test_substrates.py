"""Data pipeline, checkpointing, optimizer, and compression tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data.graph import block_sizes, sample_blocks, synthetic_graph, to_edge_list
from repro.data.loader import LoaderState, PrefetchIterator, ShardedLoader
from repro.data.recsys import ClickLogGenerator
from repro.data.retrieval import SyntheticRetrievalCorpus, hash_tokenize
from repro.optim import adamw, linear_warmup_linear_decay
from repro.optim.compression import compress_with_feedback, init_error_feedback


# --------------------------------------------------------------------- loader
def test_loader_determinism_and_epoch_rollover():
    l1 = ShardedLoader(100, 20, seed=7)
    seq1 = [l1.next_indices() for _ in range(12)]  # crosses epoch boundary (5/epoch)
    l2 = ShardedLoader(100, 20, seed=7)
    seq2 = [l2.next_indices() for _ in range(12)]
    for a, b in zip(seq1, seq2):
        np.testing.assert_array_equal(a, b)
    assert l1.state.epoch == 2 and l1.state.step == 2


def test_loader_host_sharding_partitions_global_batch():
    hosts = [ShardedLoader(64, 16, seed=3, host_id=h, n_hosts=4) for h in range(4)]
    parts = [h.next_indices() for h in hosts]
    union = np.sort(np.concatenate(parts))
    ref = np.sort(ShardedLoader(64, 16, seed=3).next_indices())
    np.testing.assert_array_equal(union, ref)
    assert all(len(p) == 4 for p in parts)


def test_loader_elastic_resume_replays_same_globals():
    """Resume with a different host count must replay the same global stream."""
    l4 = ShardedLoader(128, 32, seed=1, n_hosts=1)
    for _ in range(2):
        l4.next_indices()
    saved = l4.state.to_dict()
    # resume as 2 hosts from the saved state
    h0 = ShardedLoader(128, 32, seed=1, host_id=0, n_hosts=2, state=LoaderState.from_dict(saved))
    h1 = ShardedLoader(128, 32, seed=1, host_id=1, n_hosts=2, state=LoaderState.from_dict(saved))
    union = np.sort(np.concatenate([h0.next_indices(), h1.next_indices()]))
    ref = np.sort(ShardedLoader(128, 32, seed=1).global_indices_for(saved["epoch"], saved["step"]))
    np.testing.assert_array_equal(union, ref)


def test_prefetch_iterator():
    counter = {"n": 0}

    def make():
        counter["n"] += 1
        return {"x": np.full((2,), counter["n"])}

    it = PrefetchIterator(make, depth=2)
    got = [next(it)["x"][0] for _ in range(5)]
    it.close()
    assert got == sorted(got)  # in order
    assert got[0] == 1


# ----------------------------------------------------------------- retrieval
def test_synthetic_corpus_learnable_structure():
    c = SyntheticRetrievalCorpus(n_passages=64, seed=0)
    b = c.batch(np.arange(8))
    assert b["query"].shape == (8, 16)
    assert b["passage_pos"].shape == (8, 32)
    assert b["passage_hard"].shape == (8, 1, 32)
    # hard negative shares the topic prefix with the positive
    np.testing.assert_array_equal(
        b["passage_pos"][:, 1:5] == b["passage_hard"][:, 0, 1:5],
        np.ones((8, 4), bool),
    )


def test_hash_tokenizer_deterministic():
    a = hash_tokenize("the quick brown fox", 1000, 8)
    b = hash_tokenize("the quick brown fox", 1000, 8)
    np.testing.assert_array_equal(a, b)
    assert a[0] == 1 and a.shape == (8,)


# --------------------------------------------------------------------- graph
def test_neighbor_sampler_shapes_and_validity():
    g = synthetic_graph(500, 8, 16, 5, seed=0)
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, 500, 32)
    nodes, src, dst, mask = sample_blocks(g, seeds, [5, 3], rng)
    max_nodes, max_edges = block_sizes(32, [5, 3])
    assert nodes.shape == (max_nodes,)
    assert src.shape == dst.shape == (max_edges,)
    assert mask.all()
    # every edge points to a valid node position
    assert src.max() < max_nodes and dst.max() < max_nodes
    # messages flow from later layers toward the seeds
    assert (dst < src).all()


def test_edge_list_roundtrip():
    g = synthetic_graph(100, 4, 8, 3, seed=1)
    dst, src, dist = to_edge_list(g)
    assert len(dst) == g.n_edges == len(src) == len(dist)


# -------------------------------------------------------------------- recsys
def test_clicklog_planted_signal():
    gen = ClickLogGenerator(vocab_sizes=(100, 50, 20), n_dense=4, seed=0)
    b = gen.batch(512, step=0)
    assert b["dense"].shape == (512, 4)
    assert b["sparse"].shape == (512, 3)
    assert (b["sparse"] < np.array([100, 50, 20])).all()
    assert 0.05 < b["labels"].mean() < 0.95
    b2 = gen.batch(512, step=0)
    np.testing.assert_array_equal(b["sparse"], b2["sparse"])  # deterministic


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
        "b": {"c": jnp.ones((4,), jnp.int32)},
    }
    save_checkpoint(str(tmp_path), 5, tree)
    template = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored, step = restore_checkpoint(str(tmp_path), template)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]), np.asarray(tree["b"]["c"]))


def test_checkpoint_skips_corrupt_and_falls_back(tmp_path):
    tree = {"w": jnp.ones((3,))}
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, jax.tree_util.tree_map(lambda x: x * 2, tree))
    # corrupt the newest checkpoint's data file
    import glob

    victim = glob.glob(str(tmp_path / "step_000000000002" / "leaf_*.npy"))[0]
    with open(victim, "wb") as f:
        f.write(b"corrupt")
    restored, step = restore_checkpoint(str(tmp_path), tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.ones(3))


def test_checkpoint_partial_write_invisible(tmp_path):
    """A checkpoint dir without manifest.json (preempted mid-save) is ignored."""
    tree = {"w": jnp.ones((3,))}
    save_checkpoint(str(tmp_path), 1, tree)
    os.makedirs(tmp_path / "step_000000000009")  # no manifest
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = {"w": jnp.zeros((2,))}
    for s in range(5):
        mgr.save(s, jax.tree_util.tree_map(lambda x: x + s, tree))
    mgr.wait()
    from repro.checkpoint.checkpoint import _valid_steps

    assert _valid_steps(str(tmp_path)) == [3, 4]
    restored, step = mgr.restore_latest(tree)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.full(2, 4.0))


# --------------------------------------------------------------- compression
def test_error_feedback_unbiased_over_time():
    """bf16 compression loses bits per step, but the error-feedback residual
    keeps the *running sum* of compressed gradients within one quantum of the
    true running sum — the property that makes compressed SGD converge."""
    rng = jax.random.PRNGKey(0)
    g_true = jax.random.normal(rng, (1000,)) * 1e-3
    state = init_error_feedback({"w": g_true})
    total_q = jnp.zeros((1000,), jnp.float32)
    for _ in range(50):
        q, state = compress_with_feedback({"w": g_true}, state)
        total_q = total_q + q["w"].astype(jnp.float32)
    total_true = g_true * 50
    # without feedback, bf16 bias would accumulate linearly; with feedback the
    # residual bounds the gap by one quantization step
    gap = float(jnp.abs(total_q - total_true).max())
    one_step_q = float(jnp.abs(g_true - g_true.astype(jnp.bfloat16).astype(jnp.float32)).max())
    assert gap <= 2 * one_step_q + 1e-9, (gap, one_step_q)


def test_schedule_shapes():
    sched = linear_warmup_linear_decay(2e-5, warmup_steps=100, total_steps=1000)
    assert float(sched(0)) == 0.0
    np.testing.assert_allclose(float(sched(100)), 2e-5, rtol=1e-6)
    assert float(sched(550)) == pytest.approx(1e-5, rel=0.01)
    assert float(sched(1000)) == 0.0


def test_adamw_weight_decay_mask():
    params = {"w": jnp.ones((2,)), "ln": jnp.ones((2,))}
    tx = adamw(1e-2, weight_decay=0.1, mask=lambda p: {"w": True, "ln": False})
    state = tx.init(params)
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    updates, _ = tx.update(grads, state, params)
    assert float(jnp.abs(updates["w"]).sum()) > 0  # decay applied
    assert float(jnp.abs(updates["ln"]).sum()) == 0  # masked out
