"""Cross-device (shard_map) contrastive semantics == single-device semantics.

Runs in a subprocess with 8 host platform devices so the main test process
keeps the default 1-device view (per the dry-run isolation rule).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    sys.path.insert(0, "tests")
    from helpers import get_shard_map
    shard_map, _vma_kw = get_shard_map()
    from helpers import make_mlp_encoder, make_batch
    from repro.core import (
        ContrastiveConfig, RetrievalBatch, init_state, make_update_fn,
    )
    from repro.optim import chain, clip_by_global_norm, sgd

    assert jax.device_count() == 8, jax.device_count()
    D = 8
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))

    enc = make_mlp_encoder()
    B = 32

    def to_global_chunk_order(batch, k):
        '''Distributed accumulation chunks are per-device-local microbatches:
        global chunk j == union over devices of their j-th local chunk. The
        equivalent single-device batch is the (D, K, lk) -> (K, D, lk)
        transpose.'''
        if k == 1:
            return batch

        def perm(x):
            lk = x.shape[0] // (D * k)
            y = x.reshape((D, k, lk) + x.shape[1:])
            y = jnp.swapaxes(y, 0, 1)
            return y.reshape((x.shape[0],) + x.shape[1:])

        return RetrievalBatch(
            query=perm(batch.query),
            passage_pos=perm(batch.passage_pos),
            passage_hard=None,
        )

    def run(method, dp_axis, k=1, bank=0, steps=3):
        cfg = ContrastiveConfig(
            method=method, accumulation_steps=k, bank_size=bank, dp_axis=dp_axis
        )
        tx = chain(clip_by_global_norm(2.0), sgd(0.05))
        state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
        update = make_update_fn(enc, tx, cfg)
        if dp_axis is not None:
            batch_spec = RetrievalBatch(
                query=P(("pod", "data")),
                passage_pos=P(("pod", "data")),
                passage_hard=None,
            )
            update = shard_map(
                update,
                mesh=mesh,
                in_specs=(P(), batch_spec),
                out_specs=(P(), P()),
                **_vma_kw,
            )
        update = jax.jit(update)
        losses = []
        for i in range(steps):
            batch = make_batch(jax.random.PRNGKey(100 + i), B)
            if dp_axis is None:
                batch = to_global_chunk_order(batch, k)
            state, m = update(state, batch)
            losses.append(float(m.loss))
        return state, losses

    # bank sizes for the full-batch (rep_cache) compositions are kept larger
    # than steps*B so FIFO eviction order (which differs between the
    # device-major and chunk-major global orders) cannot enter the math
    for method, kw in [
        ("dpr", {}),
        ("grad_accum", dict(k=2)),
        ("grad_cache", dict(k=2)),
        ("contaccum", dict(k=2, bank=16)),
        ("contcache", dict(k=2, bank=128)),
        ("prebatch_cache", dict(k=2, bank=128)),
    ]:
        s1, l1 = run(method, None, **kw)
        s8, l8 = run(method, ("pod", "data"), **kw)
        np.testing.assert_allclose(l1, l8, rtol=2e-4, err_msg=method)
        for a, b in zip(
            jax.tree_util.tree_leaves(s1.params), jax.tree_util.tree_leaves(s8.params)
        ):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-6, err_msg=method
            )
        print(f"OK {method}: dist == single-device, losses {l1}")
    print("ALL-OK")
    """
)


@pytest.mark.slow
def test_cross_device_negatives_match_single_device():
    _run_subprocess(SCRIPT)


SHARDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    sys.path.insert(0, "tests")
    from helpers import get_shard_map, make_mlp_encoder, make_batch
    shard_map, _vma_kw = get_shard_map()
    from repro.core import (
        ContrastiveConfig, RetrievalBatch, init_state, make_update_fn,
    )
    from repro.distribution.sharding import contrastive_state_spec
    from repro.optim import chain, clip_by_global_norm, sgd

    assert jax.device_count() == 8, jax.device_count()
    D = 8
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
    DP = ("pod", "data")

    enc = make_mlp_encoder()
    B = 32

    def to_global_chunk_order(batch, k):
        if k == 1:
            return batch

        def perm(x):
            lk = x.shape[0] // (D * k)
            y = x.reshape((D, k, lk) + x.shape[1:])
            y = jnp.swapaxes(y, 0, 1)
            return y.reshape((x.shape[0],) + x.shape[1:])

        return RetrievalBatch(
            query=perm(batch.query),
            passage_pos=perm(batch.passage_pos),
            passage_hard=None,
        )

    def run(method, distributed, k, bank, loss_impl, shard_banks, steps=3):
        cfg = ContrastiveConfig(
            method=method, accumulation_steps=k, bank_size=bank,
            loss_impl=loss_impl,
            dp_axis=DP if distributed else None,
            shard_banks=shard_banks and distributed,
        )
        tx = chain(clip_by_global_norm(2.0), sgd(0.05))
        state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
        update = make_update_fn(enc, tx, cfg)
        if distributed:
            state_spec = contrastive_state_spec(DP, cfg.shard_banks)
            batch_spec = RetrievalBatch(
                query=P(DP), passage_pos=P(DP), passage_hard=None
            )
            update = shard_map(
                update,
                mesh=mesh,
                in_specs=(state_spec, batch_spec),
                out_specs=(state_spec, P()),
                **_vma_kw,
            )
        update = jax.jit(update)
        losses, fills = [], []
        for i in range(steps):
            batch = make_batch(jax.random.PRNGKey(100 + i), B)
            if not distributed:
                batch = to_global_chunk_order(batch, k)
            state, m = update(state, batch)
            losses.append(float(m.loss))
            fills.append((float(m.bank_fill_q), float(m.bank_fill_p)))
        return state, losses, fills

    # bank sizes chosen so the banks WRAP mid-trajectory for contaccum
    # (16 < 3 steps x 32 rows) and stay eviction-order-safe for the
    # full-batch contcache (128 > 3 x 32), on both loss backends
    for method, k, bank in [("contaccum", 2, 16), ("contcache", 2, 128)]:
        for loss_impl in ("dense", "fused"):
            tag = f"{method}/{loss_impl}/sharded"
            s1, l1, f1 = run(method, False, k, bank, loss_impl, False)
            s8, l8, f8 = run(method, True, k, bank, loss_impl, True)
            np.testing.assert_allclose(l1, l8, rtol=2e-4, err_msg=tag)
            np.testing.assert_allclose(f1, f8, rtol=0, err_msg=tag)
            for a, b in zip(
                jax.tree_util.tree_leaves(s1.params),
                jax.tree_util.tree_leaves(s8.params),
            ):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-6,
                    err_msg=tag,
                )
            # the gathered shard-major bank union must equal the replicated
            # single-device ring: slot-exact for the scan path (chunk order
            # is aligned by to_global_chunk_order); as a row-set for the
            # rep_cache path, whose device-major merge is a permutation of
            # the single-device chunk-major push order (the loss is
            # order-invariant given per-slot label alignment)
            for bank_name in ("bank_q", "bank_p"):
                b1, b8 = getattr(s1, bank_name), getattr(s8, bank_name)
                assert int(b1.head) == int(b8.head), tag
                assert int(b1.valid.sum()) == int(b8.valid.sum()), tag
                r1 = np.asarray(b1.buf)[np.asarray(b1.valid)]
                r8 = np.asarray(b8.buf)[np.asarray(b8.valid)]
                if method == "contaccum":
                    np.testing.assert_array_equal(
                        np.asarray(b1.valid), np.asarray(b8.valid), err_msg=tag
                    )
                    np.testing.assert_array_equal(
                        np.asarray(b1.age), np.asarray(b8.age), err_msg=tag
                    )
                else:
                    order1 = np.lexsort(r1.T)
                    order8 = np.lexsort(r8.T)
                    r1, r8 = r1[order1], r8[order8]
                np.testing.assert_allclose(r1, r8, rtol=2e-4, atol=2e-6,
                                           err_msg=tag)
            print(f"OK {tag}: dist == single-device, losses {l1}")
    print("ALL-OK")
    """
)


@pytest.mark.slow
def test_sharded_banks_match_single_device():
    """shard_banks=True: per-device capacity/D bank shards + gathered-column
    loss reproduce the single-device replicated-bank trajectory (params,
    banks, fills, losses) for contaccum and contcache on both backends."""
    _run_subprocess(SHARDED_SCRIPT)


def _run_subprocess(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:tests"
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL-OK" in proc.stdout
