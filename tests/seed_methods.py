"""Reference implementations for gradient-parity testing.

These are verbatim copies of the original monolithic update builders from
``core/methods.py`` as of the seed commit (before the `StepProgram`
redesign). The composed programs must reproduce their gradients, metrics and
bank evolution exactly — tests/test_step_program.py enforces it. Do NOT
refactor these to use the new API; their value is being frozen history.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.treemath import tree_add, tree_scale, tree_zeros_like, tree_global_norm
from repro.core.dist import DistCtx
from repro.core.loss import LossAux, contrastive_step_loss
from repro.core.memory_bank import BankState, clear, push_pair
from repro.core.types import (
    ContrastiveConfig,
    ContrastiveState,
    DualEncoder,
    RetrievalBatch,
    StepMetrics,
    chunk_tree,
    flatten_hard,
    subtree_norm,
)


def _encode_chunk(encoder: DualEncoder, params, chunk: RetrievalBatch):
    q = encoder.encode_query(params, chunk.query)
    pp = encoder.encode_passage(params, chunk.passage_pos)
    ph = None
    if chunk.passage_hard is not None:
        ph = encoder.encode_passage(params, flatten_hard(chunk.passage_hard))
    return q, pp, ph


def _metrics(grads, aux: LossAux, bank_q: BankState, bank_p: BankState) -> StepMetrics:
    gq = subtree_norm(grads, "query")
    gp = subtree_norm(grads, "passage")
    return StepMetrics(
        loss=aux.loss,
        accuracy=aux.accuracy,
        grad_norm=tree_global_norm(grads),
        grad_norm_query=gq,
        grad_norm_passage=gp,
        grad_norm_ratio=gp / jnp.maximum(gq, 1e-12),
        n_negatives=aux.n_negatives,
        bank_fill_q=bank_q.valid.sum().astype(jnp.float32) if bank_q.buf.shape[0] else jnp.zeros(()),
        bank_fill_p=bank_p.valid.sum().astype(jnp.float32) if bank_p.buf.shape[0] else jnp.zeros(()),
    )


def _apply(state: ContrastiveState, grads, tx, bank_q, bank_p) -> ContrastiveState:
    updates, opt_state = tx.update(grads, state.opt_state, state.params)
    from repro.optim.adamw import apply_updates

    params = apply_updates(state.params, updates)
    return ContrastiveState(
        step=state.step + 1,
        params=params,
        opt_state=opt_state,
        bank_q=bank_q,
        bank_p=bank_p,
    )


def make_dpr_update(encoder: DualEncoder, tx, cfg: ContrastiveConfig):
    ctx = DistCtx(cfg.dp_axis)

    def update(state: ContrastiveState, batch: RetrievalBatch):
        def loss_fn(params):
            q, pp, ph = _encode_chunk(encoder, params, batch)
            return contrastive_step_loss(
                q, pp, ph, None, None, temperature=cfg.temperature, ctx=ctx
            )

        (_, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        grads = ctx.psum_tree(grads)
        new_state = _apply(state, grads, tx, state.bank_q, state.bank_p)
        return new_state, _metrics(grads, aux, state.bank_q, state.bank_p)

    return update


def make_grad_accum_update(encoder: DualEncoder, tx, cfg: ContrastiveConfig):
    ctx = DistCtx(cfg.dp_axis)
    k = cfg.accumulation_steps

    def update(state: ContrastiveState, batch: RetrievalBatch):
        chunks = RetrievalBatch(
            query=chunk_tree(batch.query, k),
            passage_pos=chunk_tree(batch.passage_pos, k),
            passage_hard=None
            if batch.passage_hard is None
            else chunk_tree(batch.passage_hard, k),
        )

        def body(grads_acc, chunk):
            def loss_fn(params):
                q, pp, ph = _encode_chunk(encoder, params, chunk)
                return contrastive_step_loss(
                    q, pp, ph, None, None, temperature=cfg.temperature, ctx=ctx
                )

            (_, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
            return tree_add(grads_acc, g), aux

        grads, auxs = jax.lax.scan(
            body,
            tree_zeros_like(state.params),
            chunks,
        )
        grads = ctx.psum_tree(tree_scale(grads, 1.0 / k))
        aux = LossAux(
            loss=auxs.loss.mean(),
            accuracy=auxs.accuracy.mean(),
            n_rows=auxs.n_rows.sum(),
            n_negatives=auxs.n_negatives.mean(),
            q_global=auxs.q_global,
            p_global=auxs.p_global,
        )
        new_state = _apply(state, grads, tx, state.bank_q, state.bank_p)
        return new_state, _metrics(grads, aux, state.bank_q, state.bank_p)

    return update


def make_grad_cache_update(encoder: DualEncoder, tx, cfg: ContrastiveConfig):
    ctx = DistCtx(cfg.dp_axis)
    k = cfg.accumulation_steps

    def update(state: ContrastiveState, batch: RetrievalBatch):
        chunks = RetrievalBatch(
            query=chunk_tree(batch.query, k),
            passage_pos=chunk_tree(batch.passage_pos, k),
            passage_hard=None
            if batch.passage_hard is None
            else chunk_tree(batch.passage_hard, k),
        )
        has_hard = batch.passage_hard is not None

        def fwd(_, chunk):
            q, pp, ph = _encode_chunk(encoder, state.params, chunk)
            ph = jnp.zeros((0, q.shape[-1]), q.dtype) if ph is None else ph
            return None, (q, pp, ph)

        _, (qs, pps, phs) = jax.lax.scan(fwd, None, chunks)
        qs, pps, phs = map(jax.lax.stop_gradient, (qs, pps, phs))

        def merge(x):  # (K, local, d) -> (K*local, d)
            return x.reshape((-1, x.shape[-1]))

        def rep_loss(q_all, pp_all, ph_all):
            return contrastive_step_loss(
                q_all,
                pp_all,
                ph_all if has_hard else None,
                None,
                None,
                temperature=cfg.temperature,
                ctx=ctx,
            )

        (_, aux), rep_grads = jax.value_and_grad(rep_loss, argnums=(0, 1, 2), has_aux=True)(
            merge(qs), merge(pps), merge(phs)
        )
        gq = rep_grads[0].reshape(qs.shape)
        gpp = rep_grads[1].reshape(pps.shape)
        gph = rep_grads[2].reshape(phs.shape)

        def bwd(grads_acc, inp):
            chunk, (gq_k, gpp_k, gph_k) = inp

            def enc(params):
                q, pp, ph = _encode_chunk(encoder, params, chunk)
                ph = jnp.zeros((0, q.shape[-1]), q.dtype) if ph is None else ph
                return (q, pp, ph)

            _, vjp_fn = jax.vjp(enc, state.params)
            (g,) = vjp_fn((gq_k, gpp_k, gph_k))
            return tree_add(grads_acc, g), None

        grads, _ = jax.lax.scan(
            bwd, tree_zeros_like(state.params), (chunks, (gq, gpp, gph))
        )
        grads = ctx.psum_tree(grads)
        new_state = _apply(state, grads, tx, state.bank_q, state.bank_p)
        return new_state, _metrics(grads, aux, state.bank_q, state.bank_p)

    return update


def make_contaccum_update(encoder: DualEncoder, tx, cfg: ContrastiveConfig):
    ctx = DistCtx(cfg.dp_axis)
    k = cfg.accumulation_steps

    def update(state: ContrastiveState, batch: RetrievalBatch):
        chunks = RetrievalBatch(
            query=chunk_tree(batch.query, k),
            passage_pos=chunk_tree(batch.passage_pos, k),
            passage_hard=None
            if batch.passage_hard is None
            else chunk_tree(batch.passage_hard, k),
        )
        bank_q0 = clear(state.bank_q) if cfg.reset_banks_each_update else state.bank_q
        bank_p0 = clear(state.bank_p) if cfg.reset_banks_each_update else state.bank_p

        def body(carry, chunk):
            grads_acc, bank_q, bank_p = carry

            def loss_fn(params):
                q, pp, ph = _encode_chunk(encoder, params, chunk)
                return contrastive_step_loss(
                    q,
                    pp,
                    ph,
                    bank_q,
                    bank_p,
                    temperature=cfg.temperature,
                    ctx=ctx,
                )

            (_, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
            bank_q, bank_p = push_pair(bank_q, bank_p, aux.q_global, aux.p_global, state.step)
            return (tree_add(grads_acc, g), bank_q, bank_p), aux

        (grads, bank_q, bank_p), auxs = jax.lax.scan(
            body, (tree_zeros_like(state.params), bank_q0, bank_p0), chunks
        )
        grads = ctx.psum_tree(tree_scale(grads, 1.0 / k))
        aux = LossAux(
            loss=auxs.loss.mean(),
            accuracy=auxs.accuracy.mean(),
            n_rows=auxs.n_rows.sum(),
            n_negatives=auxs.n_negatives.mean(),
            q_global=auxs.q_global,
            p_global=auxs.p_global,
        )
        new_state = _apply(state, grads, tx, bank_q, bank_p)
        return new_state, _metrics(grads, aux, bank_q, bank_p)

    return update


SEED_BUILDERS = {
    "dpr": make_dpr_update,
    "grad_accum": make_grad_accum_update,
    "grad_cache": make_grad_cache_update,
    "contaccum": make_contaccum_update,
}
