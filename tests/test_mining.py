"""The asynchronous hard-negative mining subsystem (repro/mining).

Covers the ISSUE's contract: synchronous-mode trajectory determinism,
teleportation band filtering, async-vs-sync table equivalence at a refresh
barrier, mined x {direct,scan,rep_cache} x {dense,fused} composition parity,
checkpoint restore mid-refresh, the PrefetchIterator exception-swallowing
regression, and the LoaderState mined-stamp round trip.

Runs in its own CI job (like the ring-parity suite); tier-1 ignores it.
"""

from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from helpers import make_mlp_encoder

from repro.core.step_program import build_step_program, init_state
from repro.core.types import ContrastiveConfig, RetrievalBatch
from repro.data.loader import (
    LoaderState,
    MinedNegativeInjector,
    PrefetchIterator,
    ShardedLoader,
)
from repro.mining import (
    HardNegativeMiner,
    MinerConfig,
    NegativeTable,
    NegativeTableBuffer,
    empty_table,
    teleport_filter,
)
from repro.optim import chain, clip_by_global_norm, sgd
from repro.runtime.trainer import PeriodicHook, Trainer, TrainerConfig

DIM = 16
N_PASSAGES = 48


def _vec_corpus(seed: int = 0):
    """Vector-'token' corpus for the MLP dual encoder: query i's gold
    passage is passage i (the SyntheticRetrievalCorpus alignment)."""
    rng = np.random.default_rng(seed)
    passages = rng.normal(size=(N_PASSAGES, DIM)).astype(np.float32)
    queries = (passages + 0.1 * rng.normal(size=passages.shape)).astype(np.float32)
    return queries, passages


def _miner_cfg(**kw) -> MinerConfig:
    base = dict(
        refresh_every=3, top_k=8, n_negatives=2, depth_lo=1, depth_hi=8,
        sync=True, query_batch=32,
    )
    base.update(kw)
    return MinerConfig(**base)


def _make_miner(seed: int = 0, **cfg_kw):
    enc = make_mlp_encoder()
    params = enc.init(jax.random.PRNGKey(seed))
    queries, passages = _vec_corpus(seed)
    miner = HardNegativeMiner(
        enc, _miner_cfg(**cfg_kw), queries=queries, passages=passages
    )
    return miner, params, queries, passages


# ------------------------------------------------------------- config/table
def test_miner_config_validation():
    with pytest.raises(ValueError, match="band"):
        _miner_cfg(depth_lo=5, depth_hi=5).validate()
    with pytest.raises(ValueError, match="cover the teleportation band"):
        _miner_cfg(top_k=4, depth_hi=8).validate()
    with pytest.raises(ValueError, match="n_negatives"):
        _miner_cfg(depth_lo=1, depth_hi=2, n_negatives=4).validate()
    with pytest.raises(ValueError, match="refresh_every"):
        _miner_cfg(refresh_every=0).validate()
    _miner_cfg().validate()  # the defaults are a valid point


def test_table_swap_is_shape_stable_and_immutable():
    buf = NegativeTableBuffer(empty_table(4, 2))
    t = NegativeTable(ids=np.zeros((4, 2), np.int32), step=1, version=1)
    old = buf.swap(t)
    assert old.version == 0 and buf.read() is t
    with pytest.raises(ValueError, match="shape changed"):
        buf.swap(NegativeTable(ids=np.zeros((4, 3), np.int32)))
    with pytest.raises(ValueError):  # published tables are read-only
        buf.read().ids[0, 0] = 7


# ------------------------------------------------------- teleportation band
def test_teleport_filter_gold_excluded_and_band_respected():
    # one query: ranked ids with gold sitting at rank 1
    ids = np.array([[7, 0, 3, 9, 5, 2]])
    scores = np.array([[0.9, 0.8, 0.7, 0.6, 0.5, 0.4]], np.float32)
    gold = np.array([0])
    # band [0, 5) over gold-excluded ranks: [7, 3, 9, 5, 2]; margin 0 drops
    # candidates scoring >= gold's 0.8 -> 7 (0.9) is out
    out = teleport_filter(
        ids, scores, gold, depth_lo=0, depth_hi=5, margin=0.0, n_out=3
    )
    assert out.tolist() == [[3, 9, 5]]
    assert not (out == 0).any()  # gold never mined
    # band [2, 4): gold-excluded ranks 2..3 -> [9, 5]
    out = teleport_filter(
        ids, scores, gold, depth_lo=2, depth_hi=4, margin=0.0, n_out=3
    )
    assert out.tolist() == [[9, 5, -1]]  # under-filled band pads -1
    # margin reaches into the band: only scores < 0.8 - 0.15 survive
    out = teleport_filter(
        ids, scores, gold, depth_lo=0, depth_hi=5, margin=0.15, n_out=3
    )
    assert out.tolist() == [[9, 5, 2]]  # 7 (0.9) and 3 (0.7 >= 0.65) dropped
    # tighter: margin 0.25 -> only scores < 0.55 survive: [5, 2]
    out = teleport_filter(
        ids, scores, gold, depth_lo=0, depth_hi=5, margin=0.25, n_out=3
    )
    assert out.tolist() == [[5, 2, -1]]


def test_teleport_filter_gold_not_retrieved_uses_top_score():
    ids = np.array([[7, 3, 9]])
    scores = np.array([[0.9, 0.5, 0.4]], np.float32)
    gold = np.array([0])  # not in the list
    out = teleport_filter(
        ids, scores, gold, depth_lo=0, depth_hi=3, margin=0.0, n_out=3
    )
    # reference = top score 0.9: rank-0 (7) can't beat itself -> dropped
    assert out.tolist() == [[3, 9, -1]]


def test_miner_never_mines_gold():
    miner, params, *_ = _make_miner()
    table = miner.refresh(params, step=0)
    for i in range(table.n_queries):
        assert i not in table.ids[i]


# -------------------------------------------------------------- determinism
def _train(sync: bool, seed: int = 0, steps: int = 9, ckpt_dir=None):
    """A tiny mined-negatives training run through the real Trainer."""
    enc = make_mlp_encoder()
    queries, passages = _vec_corpus(seed)
    miner = HardNegativeMiner(
        enc, _miner_cfg(sync=sync), queries=queries, passages=passages
    )
    loader = ShardedLoader(N_PASSAGES, 8, seed=seed)
    injector = MinedNegativeInjector(
        miner.buffer.read, N_PASSAGES, seed=seed,
        state=loader.state, on_step=miner.note_step,
    )
    cfg = ContrastiveConfig(method="mined", temperature=1.0)
    tx = chain(clip_by_global_norm(1.0), sgd(0.05))
    program = build_step_program(enc, tx, cfg)
    update = jax.jit(program.update)
    state = init_state(jax.random.PRNGKey(seed), enc, tx, cfg)

    def next_batch(step):
        idx = loader.next_indices()
        mined = injector.mined_ids(idx, gold=idx, step=step)
        return RetrievalBatch(
            query=jnp.asarray(queries[idx]),
            passage_pos=jnp.asarray(passages[idx]),
            passage_hard=jnp.asarray(passages[mined]),
        )

    trainer = Trainer(
        TrainerConfig(
            total_steps=steps, log_every=1000,
            checkpoint_dir=ckpt_dir, checkpoint_every=4,
        ),
        update,
        next_batch,
        loader_state=loader.state,
        hooks=[
            PeriodicHook(every=3, fn=miner.refresh_hook, prefix="mine/", name="mine")
        ],
        aux_state=miner,
    )
    state, report = trainer.run(state)
    miner.close()
    return state, report, miner, loader


def test_sync_mode_trajectory_is_seed_deterministic():
    _, r1, m1, _ = _train(sync=True)
    _, r2, m2, _ = _train(sync=True)
    l1 = [row["loss"] for row in r1.history]
    l2 = [row["loss"] for row in r2.history]
    assert l1 == l2  # bit-identical, not approx
    assert np.array_equal(m1.buffer.read().ids, m2.buffer.read().ids)
    # the refresh hook fired on cadence and left its metrics in the history
    mined_rows = [row for row in r1.history if "mine/table_version" in row]
    assert [int(row["step"]) for row in mined_rows] == [2, 5, 8]
    assert mined_rows[-1]["mine/refreshes"] == 3.0


def test_different_seed_changes_trajectory():
    _, r1, _, _ = _train(sync=True, seed=0)
    _, r2, _, _ = _train(sync=True, seed=1)
    assert [row["loss"] for row in r1.history] != [row["loss"] for row in r2.history]


# ------------------------------------------------------------ async pipeline
def test_async_matches_sync_at_refresh_barrier():
    m_sync, params, *_ = _make_miner(sync=True)
    m_async, _, *_ = _make_miner(sync=False)
    t_sync = m_sync.refresh(params, step=7)
    assert m_async.refresh_async(params, step=7)
    m_async.wait()  # the barrier
    t_async = m_async.buffer.read()
    assert np.array_equal(t_sync.ids, t_async.ids)
    assert (t_sync.step, t_sync.version) == (t_async.step, t_async.version)


def test_async_requests_skip_while_in_flight():
    miner, params, *_ = _make_miner(sync=False)
    gate = threading.Event()
    orig = miner._mine

    def gated(p, s):
        gate.wait(timeout=10)
        return orig(p, s)

    miner._mine = gated
    assert miner.refresh_async(params, 0)
    assert not miner.refresh_async(params, 1)  # one refresh at a time
    assert miner.skipped == 1
    gate.set()
    miner.wait()
    assert miner.refreshes == 1


def test_async_worker_exception_reraises_on_consumer_side():
    miner, params, *_ = _make_miner(sync=False)

    def boom(p, s):
        raise RuntimeError("index rebuild exploded")

    miner._mine = boom
    miner.refresh_async(params, 0)
    with pytest.raises(RuntimeError, match="index rebuild exploded"):
        miner.wait()
    # the failure is delivered once, then the miner is usable again
    del miner._mine  # restore the class implementation
    miner.refresh(params, 1)
    assert miner.buffer.read().version == 1


def test_async_overlap_counts_training_steps():
    miner, params, *_ = _make_miner(sync=False)
    gate = threading.Event()
    orig = miner._mine

    def gated(p, s):
        gate.wait(timeout=10)
        return orig(p, s)

    miner._mine = gated
    miner.refresh_async(params, step=10)
    for s in range(10, 15):  # 5 training steps land while mining runs
        miner.note_step(s)
    gate.set()
    miner.wait()
    assert miner.last_overlap == 4  # steps 11..14 observed after the start


# ------------------------------------------------- injector + loader state
def test_injector_fallback_is_deterministic_and_gold_free():
    buf = NegativeTableBuffer(empty_table(N_PASSAGES, 2))
    state = LoaderState()
    inj = MinedNegativeInjector(
        buf.read, N_PASSAGES, seed=3, state=state
    )
    idx = np.arange(8)
    a = inj.mined_ids(idx, gold=idx, step=5)
    b = inj.mined_ids(idx, gold=idx, step=5)
    assert np.array_equal(a, b)  # same (seed, step) -> same fallback
    assert (a >= 0).all() and (a != idx[:, None]).all()
    assert (state.mined_step, state.mined_version) == (-1, 0)
    c = inj.mined_ids(idx, gold=idx, step=6)
    assert not np.array_equal(a, c)  # fallback reshuffles per step


def test_injector_joins_table_and_stamps_state():
    ids = np.tile(np.array([[5, 9]], np.int32), (N_PASSAGES, 1))
    ids[0] = (-1, 9)  # one empty slot -> fallback fills it
    buf = NegativeTableBuffer(empty_table(N_PASSAGES, 2))
    buf.swap(NegativeTable(ids=ids, step=12, version=2))
    state = LoaderState()
    inj = MinedNegativeInjector(buf.read, N_PASSAGES, seed=0, state=state)
    got = inj.mined_ids(np.arange(4), gold=np.arange(4), step=20)
    assert got[1:].tolist() == [[5, 9]] * 3
    assert got[0, 1] == 9 and got[0, 0] not in (-1, 0)  # filled, non-gold
    assert (state.mined_step, state.mined_version) == (12, 2)


def test_loader_state_round_trips_mined_stamps():
    st = LoaderState(epoch=2, step=7, mined_step=40, mined_version=3)
    assert LoaderState.from_dict(st.to_dict()) == st
    # dicts saved before the stamps existed still restore
    legacy = LoaderState.from_dict({"epoch": 1, "step": 2})
    assert (legacy.mined_step, legacy.mined_version) == (-1, 0)


def test_prefetch_close_surfaces_unseen_worker_exception():
    consumed = threading.Event()
    n = {"calls": 0}

    def fn():
        n["calls"] += 1
        if n["calls"] == 1:
            return {"x": np.zeros(1)}
        consumed.wait(timeout=10)
        raise RuntimeError("worker died after the consumer stopped reading")

    it = PrefetchIterator(fn, depth=1)
    assert "x" in next(it)
    consumed.set()  # let the worker crash producing the item nobody reads
    deadline = time.monotonic() + 10
    while it._exc is None and time.monotonic() < deadline:
        time.sleep(0.01)
    with pytest.raises(RuntimeError, match="worker died"):
        it.close()  # the old close() swallowed this silently


def test_prefetch_close_does_not_replay_delivered_exception():
    def fn():
        raise RuntimeError("boom")

    it = PrefetchIterator(fn, depth=1)
    with pytest.raises(RuntimeError, match="boom"):
        next(it)
    it.close()  # already delivered via __next__: close stays quiet


# ------------------------------------------------------- composition parity
@pytest.mark.parametrize("backprop", ["direct", "scan", "rep_cache"])
@pytest.mark.parametrize("loss_impl", ["dense", "fused"])
def test_mined_composes_with_every_strategy_and_backend(backprop, loss_impl):
    """negatives='mined' is mathematically in-batch over the widened batch:
    one update must match the in_batch source bit-for-bit on the same
    (mined-column-carrying) batch, for every strategy x loss backend."""
    enc = make_mlp_encoder()
    queries, passages = _vec_corpus()
    miner, params, *_ = _make_miner()
    table = miner.refresh(params, 0)
    idx = np.arange(8)
    batch = RetrievalBatch(
        query=jnp.asarray(queries[idx]),
        passage_pos=jnp.asarray(passages[idx]),
        passage_hard=jnp.asarray(passages[table.ids[idx]]),
    )

    def run(negatives):
        cfg = ContrastiveConfig(
            method="dpr",
            negatives=negatives,
            backprop=backprop,
            accumulation_steps=1 if backprop == "direct" else 2,
            loss_impl=loss_impl,
            temperature=1.0,
        )
        tx = chain(clip_by_global_norm(1.0), sgd(0.05))
        program = build_step_program(enc, tx, cfg)
        state = init_state(jax.random.PRNGKey(0), enc, tx, cfg, params=params)
        new_state, metrics = jax.jit(program.update)(state, batch)
        return jax.device_get(metrics), jax.device_get(new_state.params)

    m_mined, p_mined = run("mined")
    m_base, p_base = run("in_batch")
    assert np.isfinite(m_mined.loss)
    assert float(m_mined.loss) == float(m_base.loss)
    for a, b in zip(jax.tree_util.tree_leaves(p_mined), jax.tree_util.tree_leaves(p_base)):
        assert np.array_equal(a, b)


def test_mined_composes_with_dual_banks():
    """contaccum x mined: the bank source keeps its rings while mined
    columns ride passage_hard — the composition builds and steps."""
    enc = make_mlp_encoder()
    queries, passages = _vec_corpus()
    miner, params, *_ = _make_miner()
    table = miner.refresh(params, 0)
    cfg = ContrastiveConfig(
        method="contaccum", accumulation_steps=2, bank_size=16, temperature=1.0
    )
    tx = chain(clip_by_global_norm(1.0), sgd(0.05))
    program = build_step_program(enc, tx, cfg)
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg, params=params)
    idx = np.arange(8)
    batch = RetrievalBatch(
        query=jnp.asarray(queries[idx]),
        passage_pos=jnp.asarray(passages[idx]),
        passage_hard=jnp.asarray(passages[table.ids[idx]]),
    )
    state, metrics = jax.jit(program.update)(state, batch)
    metrics = jax.device_get(metrics)
    assert np.isfinite(metrics.loss)
    assert float(metrics.bank_fill_p) > 0  # the banks really engaged


# --------------------------------------------------------------- checkpoint
def test_checkpoint_save_ignores_in_flight_refresh_and_restores(tmp_path):
    """state_to_save mid-refresh captures the *published* table; restoring
    it into a fresh miner reproduces that table exactly, and the restored
    miner can keep refreshing."""
    miner, params, *_ = _make_miner(sync=False)
    t1 = miner.refresh(params, step=0)  # published baseline

    gate = threading.Event()
    orig = miner._mine

    def gated(p, s):
        gate.wait(timeout=10)
        return orig(p, s)

    miner._mine = gated
    miner.refresh_async(params, step=5)  # in flight...
    saved = miner.state_to_save()        # ...checkpoint lands mid-refresh
    assert saved["meta"].tolist() == [0, 1]  # the published v1, not v2
    gate.set()
    miner.wait()
    assert miner.buffer.read().version == 2  # the refresh did finish

    restored, _, *_ = _make_miner(sync=False)
    restored.load_saved_state(saved)
    t_r = restored.buffer.read()
    assert np.array_equal(t_r.ids, t1.ids)
    assert (t_r.step, t_r.version) == (0, 1)
    t_next = restored.refresh(params, step=9)
    assert t_next.version == 2  # version continues from the restored table


def test_trainer_round_trips_miner_state_and_loader_stamps(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    _, r1, m1, l1 = _train(sync=True, steps=9, ckpt_dir=ckpt)
    t1 = m1.buffer.read()
    assert l1.state.mined_step >= 0  # batches joined a real table

    # a fresh trainer over the same dir restores and has nothing left to run
    _, r2, m2, l2 = _train(sync=True, steps=9, ckpt_dir=ckpt)
    assert r2.steps_run == 0
    assert np.array_equal(m2.buffer.read().ids, t1.ids)
    assert (m2.buffer.read().step, m2.buffer.read().version) == (t1.step, t1.version)
    assert (l2.state.mined_step, l2.state.mined_version) == (
        l1.state.mined_step, l1.state.mined_version,
    )
