"""Property-based tests (hypothesis) on the fused InfoNCE kernel invariants.

Guarded by importorskip per the tests/test_properties.py convention:
adversarially-searched counterexamples for the online-softmax identities the
blocked kernel relies on — shift invariance, block-size independence, and
exact masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.fused_infonce.ops import fused_infonce_stats
from repro.kernels.fused_infonce.ref import infonce_stats_ref

_settings = settings(max_examples=20, deadline=None)


def _problem(seed, m, n, d, mask_p=0.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    p = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, n, size=(m,)).astype(np.int32))
    valid = jnp.asarray(rng.random(n) >= mask_p)
    valid = valid.at[labels].set(True)  # each row keeps its positive column
    return q, p, labels, valid


@_settings
@given(
    m=st.integers(1, 48),
    n=st.integers(2, 96),
    d=st.integers(1, 32),
    shift=st.floats(-30.0, 30.0),
    seed=st.integers(0, 2**16),
)
def test_online_softmax_shift_invariance(m, n, d, shift, seed):
    """Adding a constant to every logit shifts lse and pos equally, so the
    per-row loss is invariant — the identity that lets the running-max
    accumulator renormalize partial sums across column blocks. The shift is
    realized in rep space: append a coordinate (1, shift) to (q, p)."""
    q, p, labels, _ = _problem(seed, m, n, d)
    q2 = jnp.concatenate([q, jnp.ones((m, 1))], axis=1)
    p2 = jnp.concatenate([p, jnp.full((n, 1), shift)], axis=1)
    lse_a, pos_a, _ = fused_infonce_stats(q, p, labels, None, 1.0, 32, 32, True)
    lse_b, pos_b, _ = fused_infonce_stats(q2, p2, labels, None, 1.0, 32, 32, True)
    np.testing.assert_allclose(
        np.asarray(lse_a - pos_a), np.asarray(lse_b - pos_b),
        rtol=2e-4, atol=2e-4,
    )


@_settings
@given(
    m=st.integers(1, 40),
    n=st.integers(2, 200),
    d=st.integers(1, 24),
    seed=st.integers(0, 2**16),
)
def test_block_size_independence(m, n, d, seed):
    """The result must not depend on the tiling: block_n in {32, 64, 128}
    (with ragged padding as needed) all agree with the dense oracle."""
    q, p, labels, valid = _problem(seed, m, n, d, mask_p=0.2)
    ref = infonce_stats_ref(q, p, labels, valid)
    outs = [
        fused_infonce_stats(q, p, labels, valid, 1.0, 32, bn, True)
        for bn in (32, 64, 128)
    ]
    for out in outs:
        for got, want in zip(out, ref):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5
            )
    # and pairwise identical across block sizes (same fp32 accumulator path)
    for out in outs[1:]:
        for a, b in zip(outs[0], out):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-6, atol=2e-6
            )


@_settings
@given(
    m=st.integers(1, 24),
    n=st.integers(2, 64),
    n_garbage=st.integers(1, 32),
    d=st.integers(1, 16),
    seed=st.integers(0, 2**16),
)
def test_masked_columns_never_affect_loss_or_grads(m, n, n_garbage, d, seed):
    """Appending arbitrarily large masked columns changes nothing: loss and
    dQ identical, and the masked columns' dP rows are exactly zero."""
    q, p, labels, _ = _problem(seed, m, n, d)
    rng = np.random.default_rng(seed + 1)
    garbage = jnp.asarray(100.0 * rng.normal(size=(n_garbage, d)).astype(np.float32))
    p2 = jnp.concatenate([p, garbage], axis=0)
    valid2 = jnp.concatenate([jnp.ones(n, bool), jnp.zeros(n_garbage, bool)])

    def loss(q_, p_, valid_):
        lse, pos, _ = fused_infonce_stats(q_, p_, labels, valid_, 1.0, 32, 32, True)
        return jnp.mean(lse - pos)

    l1, (gq1, gp1) = jax.value_and_grad(loss, argnums=(0, 1))(q, p, None)
    l2, (gq2, gp2) = jax.value_and_grad(loss, argnums=(0, 1))(q, p2, valid2)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gq1), np.asarray(gq2), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(gp1), np.asarray(gp2[:n]), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_array_equal(np.asarray(gp2[n:]), 0.0)
