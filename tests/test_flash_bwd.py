"""flash_chunked_attention (custom VJP, blockwise-recomputing backward) must
match plain_attention's value AND gradients — it is the training attention for
every LM cell."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    chunked_attention,
    flash_chunked_attention,
    plain_attention,
)


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("hk,h", [(4, 4), (2, 8)])  # MHA and GQA
@pytest.mark.parametrize("q_chunk,kv_chunk", [(8, 16), (32, 32)])
def test_flash_grads_match_plain(causal, hk, h, q_chunk, kv_chunk):
    b, sq, skv, d = 2, 32, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = _rand(ks[0], b, sq, h, d) * 0.4
    k = _rand(ks[1], b, skv, hk, d) * 0.4
    v = _rand(ks[2], b, skv, hk, d) * 0.4
    cot = _rand(ks[3], b, sq, h, d)

    def loss_flash(q, k, v):
        o = flash_chunked_attention(q, k, v, causal, None, q_chunk, kv_chunk)
        return jnp.sum(o * cot)

    def loss_plain(q, k, v):
        return jnp.sum(plain_attention(q, k, v, causal=causal) * cot)

    vf, gf = jax.value_and_grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    vp, gp = jax.value_and_grad(loss_plain, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(vf, vp, rtol=2e-5, atol=2e-5)
    for a, b_ in zip(gf, gp):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-4)


def test_flash_value_matches_chunked_with_lse():
    b, s, h, d = 1, 64, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (_rand(ki, b, s, h, d) for ki in ks)
    out, lse = chunked_attention(
        q, k, v, causal=True, q_chunk=16, kv_chunk=16, return_lse=True
    )
    ref = plain_attention(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    # lse sanity: logsumexp of the scaled logits row
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (d ** -0.5)
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    lse_ref = jax.nn.logsumexp(logits, axis=-1).transpose(0, 2, 1)
    np.testing.assert_allclose(lse, lse_ref, rtol=1e-5, atol=1e-5)


def test_flash_bf16_trains():
    b, s, h, d = 2, 64, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = _rand(ks[0], b, s, h, d).astype(jnp.bfloat16)
    k = _rand(ks[1], b, s, 2, d).astype(jnp.bfloat16)
    v = _rand(ks[2], b, s, 2, d).astype(jnp.bfloat16)

    def loss(q, k, v):
        o = flash_chunked_attention(q, k, v, True, None, 16, 32)
        return jnp.sum(o.astype(jnp.float32) ** 2)

    g = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
    for x, ref in zip(g, (q, k, v)):
        assert x.dtype == ref.dtype
        assert np.isfinite(np.asarray(x, np.float32)).all()


def test_flash_uneven_gqa_and_rect():
    """Rectangular Sq != Skv, n_rep=8 (decode-like but multi-query rows)."""
    b, sq, skv, hk, h, d = 1, 16, 64, 1, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], b, sq, h, d)
    k = _rand(ks[1], b, skv, hk, d)
    v = _rand(ks[2], b, skv, hk, d)

    def f(q, k, v):
        return flash_chunked_attention(q, k, v, False, None, 8, 16).sum()

    def f_ref(q, k, v):
        return plain_attention(q, k, v).sum()

    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gp = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gp):
        np.testing.assert_allclose(a, b_, rtol=2e-4, atol=2e-4)
