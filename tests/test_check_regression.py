"""benchmarks/check_regression.py: row classification, tolerance rules, and
CLI exit codes — the contract the benchmark-regression CI job enforces."""

import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from benchmarks.check_regression import compare, main, row_kind  # noqa: E402


def test_row_kind_classification():
    assert row_kind("dist/contaccum/bank2048/ring/step_ms") == "time"
    assert row_kind("suite/elapsed_s") == "time"
    assert row_kind("dist/transient/D8/ring/loss_grad_temp_kib") == "memory"
    assert row_kind("dist/x/bank_kib_per_dev") == "memory"
    assert row_kind("dist/x/peak_bytes") == "memory"
    assert row_kind("dist/x/n_rows") == "info"


def test_time_tolerance_is_15_percent():
    base = {"a/step_ms": 100.0}
    fails, _ = compare({"a/step_ms": 114.0}, base)
    assert fails == []
    fails, _ = compare({"a/step_ms": 116.0}, base)
    assert fails == ["a/step_ms"]


def test_memory_regresses_on_any_real_increase():
    base = {"a/temp_kib": 1000.0}
    # within the 1% float/accounting epsilon: pass
    fails, _ = compare({"a/temp_kib": 1005.0}, base)
    assert fails == []
    fails, _ = compare({"a/temp_kib": 1020.0}, base)
    assert fails == ["a/temp_kib"]
    # improvements always pass
    fails, _ = compare({"a/temp_kib": 500.0}, base)
    assert fails == []


def test_disjoint_rows_never_fail():
    # quick CI runs measure a subset of the full baseline: rows present on
    # only one side are reported but must not fail the check
    fails, lines = compare(
        {"new/step_ms": 5.0}, {"old/step_ms": 5.0, "both/temp_kib": 1.0}
    )
    assert fails == []
    report = "\n".join(lines)
    assert "NEW" in report and "MISSING" in report


def _write(path, rows):
    path.write_text(json.dumps({"suite": "x", "rows": [
        {"name": n, "value": v} for n, v in rows.items()
    ]}))


def test_cli_exit_codes(tmp_path, capsys):
    cur, base = tmp_path / "BENCH_x.json", tmp_path / "base.json"
    _write(base, {"a/step_ms": 100.0, "b/temp_kib": 10.0})

    _write(cur, {"a/step_ms": 105.0, "b/temp_kib": 10.0})
    assert main([str(cur), str(base)]) == 0
    assert "no regressions" in capsys.readouterr().out

    _write(cur, {"a/step_ms": 130.0, "b/temp_kib": 10.0})
    assert main([str(cur), str(base)]) == 1
    assert "a/step_ms" in capsys.readouterr().out

    assert main([str(tmp_path / "missing.json"), str(base)]) == 2


def test_committed_baseline_is_self_consistent():
    """The checked-in baseline compares clean against itself and covers the
    transient rows the ring path is accountable for."""
    baseline = REPO_ROOT / "benchmarks" / "baselines" / "BENCH_distributed.json"
    payload = json.loads(baseline.read_text())
    rows = {r["name"]: float(r["value"]) for r in payload["rows"]}
    fails, _ = compare(rows, rows)
    assert fails == []
    for d in (2, 4, 8):
        for mode in ("base", "all_gather", "ring"):
            for stage in ("loss_fwd", "loss_grad"):
                assert f"dist/transient/D{d}/{mode}/{stage}_temp_kib" in rows
    # the headline inequality the committed numbers must exhibit
    assert (
        rows["dist/transient/D8/ring/loss_grad_temp_kib"]
        < 0.25 * rows["dist/transient/D8/all_gather/loss_grad_temp_kib"]
    )
