"""Examples must keep running against the current StepProgram API.

quickstart.py and train_retriever.py predate the StepProgram refactors
(PRs 1-3) and silently rotted once before; this smoke imports and drives
both at toy scale so an API break fails CI instead of a user."""

import importlib.util
import os

import pytest


def _load_example(name):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "examples", f"{name}.py")
    spec = importlib.util.spec_from_file_location(f"example_{name}", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_runs_end_to_end():
    """Both phases (DPR warm-up -> explicit dual_bank x scan composition)
    plus the final top-k eval, at smoke scale."""
    mod = _load_example("quickstart")
    mod.main(warm_steps=2, steps=3, n_passages=64)


def test_serve_retrieval_runs_end_to_end():
    """The serving example on the Retriever API: index build + dynamic
    batching + blocked top-k at its (already small) default scale."""
    mod = _load_example("serve_retrieval")
    mod.main()


@pytest.mark.parametrize("extra", [
    [],                                        # the default contaccum path
    ["--precision", "bf16_banks", "--loss-impl", "fused"],
])
def test_train_retriever_runs_end_to_end(extra):
    """The production-path driver, including the new --precision flag."""
    mod = _load_example("train_retriever")
    mod.main([
        "--steps", "3",
        "--warmup-steps", "2",
        "--total-batch", "16",
        "--local-batch", "8",
        "--bank", "16",
        "--corpus", "64",
    ] + extra)
