"""Shared test fixtures: a tiny nonlinear dual encoder over vector 'tokens'.

Two-layer MLPs (separate query/passage towers) are enough to make the
GradCache identity and the gradient-norm analyses non-trivial while keeping
tests fast on CPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import DualEncoder, RetrievalBatch


def get_shard_map():
    """(shard_map, kwargs) across jax versions: >= 0.5 has jax.shard_map with
    ``check_vma``; older releases keep it in experimental with ``check_rep``.
    Delegates to the production helper so tests and launch code can't drift."""
    from repro.core.dist import get_shard_map as _impl

    return _impl()


def make_mlp_encoder(dim_in: int = 16, dim_hidden: int = 32, dim_rep: int = 8) -> DualEncoder:
    def tower_init(rng):
        k1, k2 = jax.random.split(rng)
        return {
            "w1": jax.random.normal(k1, (dim_in, dim_hidden)) * 0.3,
            "b1": jnp.zeros((dim_hidden,)),
            "w2": jax.random.normal(k2, (dim_hidden, dim_rep)) * 0.3,
            "b2": jnp.zeros((dim_rep,)),
        }

    def tower_apply(tp, x):
        h = jnp.tanh(x @ tp["w1"] + tp["b1"])
        return h @ tp["w2"] + tp["b2"]

    def init(rng):
        kq, kp = jax.random.split(rng)
        return {"query": tower_init(kq), "passage": tower_init(kp)}

    return DualEncoder(
        init=init,
        encode_query=lambda params, x: tower_apply(params["query"], x),
        encode_passage=lambda params, x: tower_apply(params["passage"], x),
        rep_dim=dim_rep,
    )


def make_batch(rng, batch_size: int, dim_in: int = 16, n_hard: int = 0) -> RetrievalBatch:
    kq, kp, kh = jax.random.split(rng, 3)
    # planted structure: positives correlated with queries so accuracy moves
    q = jax.random.normal(kq, (batch_size, dim_in))
    p = q + 0.5 * jax.random.normal(kp, (batch_size, dim_in))
    hard = None
    if n_hard > 0:
        hard = q[:, None, :] + 1.5 * jax.random.normal(kh, (batch_size, n_hard, dim_in))
    return RetrievalBatch(query=q, passage_pos=p, passage_hard=hard)
