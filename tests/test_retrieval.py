"""Retriever API tests: search-backend parity (dense vs fused vs reference,
ties, k > n, masking), BatchingServer coalescing/padding/flush semantics
(including the backlog regression), eval-path equivalence + bounded memory,
sharded-vs-replicated index parity on 8 host devices, and the end-to-end
trained-checkpoint -> serve -> recall smoke."""

import subprocess
import sys
import textwrap
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_topk.ops import fused_topk_scores
from repro.kernels.fused_topk.ref import topk_scores_ref
from repro.retrieval import (
    DenseSearchBackend,
    FusedSearchBackend,
    Retriever,
    RetrieverConfig,
    build_index_store,
    load_trained_params,
    make_server,
    resolve_search_backend,
)
from repro.runtime.server import BatchingServer


# ------------------------------------------------------- backend parity
def _rand(q, n, d, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return (
        rng.normal(size=(q, d)).astype(dtype),
        rng.normal(size=(n, d)).astype(dtype),
    )


@pytest.mark.parametrize("impl,kw", [
    ("dense", {"block": 64}),
    ("fused", {"block_q": 16, "block_n": 64}),
])
def test_backend_matches_reference(impl, kw):
    q, p = _rand(13, 517, 24)
    be = resolve_search_backend(impl, **kw)
    scores, ids = jax.jit(
        lambda a, b: be.topk(a, b, 10)
    )(jnp.asarray(q), jnp.asarray(p))
    ref_s, ref_i = topk_scores_ref(jnp.asarray(q), jnp.asarray(p), 10)
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref_i), impl)
    np.testing.assert_allclose(np.asarray(scores), np.asarray(ref_s),
                               rtol=0, atol=1e-5)
    assert np.asarray(scores).dtype == np.float32


def test_dense_fused_parity_with_ties():
    """Quantized reps force exact score ties across blocks; both backends
    must break them toward the lowest column id (lax.top_k semantics)."""
    rng = np.random.default_rng(1)
    q = rng.integers(-2, 3, size=(7, 8)).astype(np.float32)
    p = rng.integers(-2, 3, size=(200, 8)).astype(np.float32)
    p[50] = p[10]           # identical rows in different blocks -> tied scores
    p[130] = p[10]
    dense = DenseSearchBackend(block=32)
    fused = FusedSearchBackend(block_q=8, block_n=32)
    s_d, i_d = dense.topk(jnp.asarray(q), jnp.asarray(p), 12)
    s_f, i_f = fused.topk(jnp.asarray(q), jnp.asarray(p), 12)
    s_r, i_r = topk_scores_ref(jnp.asarray(q), jnp.asarray(p), 12)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_r))
    np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_f))


@pytest.mark.parametrize("impl,kw", [
    ("dense", {"block": 4}),
    ("fused", {"block_q": 8, "block_n": 4}),
])
def test_backend_k_exceeds_valid_columns(impl, kw):
    """k > n (and k > n_valid): the tail slots must come back with id -1,
    not garbage, and valid slots must still be exact."""
    q, p = _rand(3, 6, 8, seed=2)
    valid = np.array([True, False, True, True, False, True])
    be = resolve_search_backend(impl, **kw)
    scores, ids = be.topk(jnp.asarray(q), jnp.asarray(p), 9,
                          col_valid=jnp.asarray(valid))
    ref_s, ref_i = topk_scores_ref(jnp.asarray(q), jnp.asarray(p), 9,
                                   col_valid=jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(ref_i))
    assert np.all(np.asarray(ids)[:, 4:] == -1)          # only 4 valid columns
    np.testing.assert_allclose(np.asarray(scores)[:, :4],
                               np.asarray(ref_s)[:, :4], atol=1e-5)


def test_fused_bf16_index_well_separated_ids_exact():
    """bf16 queries/index (the bf16_banks serving path): ids stay exact when
    scores are separated beyond bf16 rounding; scores match the bf16
    reference matmul to documented tolerance (inputs rounded, accumulation
    fp32)."""
    rng = np.random.default_rng(3)
    d = 16
    p = rng.normal(size=(64, d)).astype(np.float32)
    p *= (1.0 + np.arange(64))[:, None]          # well-separated norms
    q = rng.normal(size=(5, d)).astype(np.float32)
    qb, pb = jnp.asarray(q, jnp.bfloat16), jnp.asarray(p, jnp.bfloat16)
    s_f, i_f = fused_topk_scores(qb, pb, 8, block_q=8, block_n=16)
    s_r, i_r = topk_scores_ref(qb, pb, 8)
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_r),
                               rtol=2e-2, atol=1e-2)
    assert np.asarray(s_f).dtype == np.float32   # fp32-scores contract


def test_resolve_search_backend_rejects_unknown():
    with pytest.raises(ValueError, match="unknown search_impl"):
        resolve_search_backend("faiss")
    with pytest.raises(ValueError, match="index_layout"):
        Retriever(None, None, RetrieverConfig(index_layout="interleaved"))
    with pytest.raises(ValueError, match="mesh"):
        Retriever(None, None, RetrieverConfig(index_layout="sharded"))


# ------------------------------------------------------------ index store
def test_index_store_pads_and_masks():
    reps = np.arange(10 * 4, dtype=np.float32).reshape(10, 4)
    store = build_index_store(
        lambda toks: jnp.asarray(toks, jnp.float32), reps,
        batch=4, dtype=jnp.bfloat16, shards=4,
    )
    assert store.reps.shape == (12, 4) and store.reps.dtype == jnp.bfloat16
    # the store stays on the host (the full matrix must never land on one
    # device; the Retriever device_puts straight into the target layout)
    assert isinstance(store.reps, np.ndarray)
    assert isinstance(store.row_valid, np.ndarray)
    assert store.n_total == 10 and store.rows_per_shard == 3
    assert np.asarray(store.row_valid).sum() == 10
    # bf16 + 4 shards: 12*4*2/4 bytes
    assert store.bytes_per_device() == 12 * 4 * 2 // 4


# ------------------------------------------------------------- batching
def test_batching_server_coalesces_backlog():
    """Regression for the _collect coalescing-under-backlog bug: the flush
    deadline was computed from the first request's *submit* time, so a
    backed-up queue degraded every batch to size 1. Pre-fill the queue
    before starting the worker: every batch must come out full."""
    done = threading.Event()

    def serve(batch):
        done.wait()          # hold the first batch until the queue backs up
        return np.arange(len(batch))[:, None], batch.sum(axis=1, keepdims=True)

    srv = BatchingServer(serve, max_batch=8, max_wait_s=0.001)
    futs = [srv.submit(np.full((4,), float(i))) for i in range(32)]
    time.sleep(0.05)         # all 32 requests sit in the queue (backlog)
    srv.start()
    done.set()
    try:
        for f in futs:
            f.get(timeout=10)
        assert srv.batch_sizes == [8, 8, 8, 8], srv.batch_sizes
    finally:
        srv.stop()


def test_batching_server_pads_to_compiled_shape_and_flushes():
    """A lone request must flush after ~max_wait_s padded to max_batch (one
    compiled shape), and each caller gets only its own row back."""
    seen = []

    def serve(batch):
        seen.append(batch.shape)
        return np.tile(batch[:, :1], (1, 3)), batch.sum(axis=1, keepdims=True)

    srv = BatchingServer(serve, max_batch=4, max_wait_s=0.02).start()
    try:
        t0 = time.monotonic()
        ids, scores = srv.query(np.full((2,), 7.0), timeout=10)
        assert time.monotonic() - t0 < 5.0
        assert seen[0] == (4, 2)             # padded to the compiled shape
        assert ids.shape == (3,) and np.all(ids == 7.0)
        assert scores.shape == (1,)
    finally:
        srv.stop()


# ---------------------------------------------------------- eval rewire
def _mlp_encoder(d_in=12, d=8):
    """Tiny deterministic linear dual encoder over float 'token' vectors."""
    from repro.core.types import DualEncoder

    def init(rng):
        kq, kp = jax.random.split(rng)
        return {
            "query": jax.random.normal(kq, (d_in, d)) * 0.5,
            "passage": jax.random.normal(kp, (d_in, d)) * 0.5,
        }

    return DualEncoder(
        init=init,
        encode_query=lambda p, x: x @ p["query"],
        encode_passage=lambda p, x: x @ p["passage"],
        rep_dim=d,
    )


class _VecCorpus:
    """eval_split-compatible corpus over raw float vectors."""

    def __init__(self, n=96, d_in=12, seed=0):
        rng = np.random.default_rng(seed)
        self.n_passages = n
        self.passages = rng.normal(size=(n, d_in)).astype(np.float32)
        self.queries = (
            self.passages + 0.05 * rng.normal(size=(n, d_in))
        ).astype(np.float32)

    def eval_split(self, n=16):
        idx = np.arange(self.n_passages - n, self.n_passages)
        return self.queries[idx], self.passages, idx


def test_evaluate_topk_matches_legacy_full_argsort():
    """The Retriever-backed eval must reproduce the old full (Q, N) score
    matrix + argsort path exactly, for both backends."""
    from repro.evaluation import evaluate_topk

    enc = _mlp_encoder()
    params = enc.init(jax.random.PRNGKey(0))
    corpus = _VecCorpus()
    queries, passages, gold = corpus.eval_split(
        n=min(256, corpus.n_passages // 4)
    )
    q = np.asarray(enc.encode_query(params, jnp.asarray(queries)))
    p = np.asarray(enc.encode_passage(params, jnp.asarray(passages)))
    order = np.argsort(-(q @ p.T), axis=1)
    legacy = {
        f"top@{k}": float(np.mean([
            gold[i] in order[i, :k] for i in range(len(gold))
        ]))
        for k in (1, 5, 20)
    }
    for impl in ("dense", "fused"):
        got = evaluate_topk(
            enc, params, corpus,
            cfg=RetrieverConfig(search_impl=impl, score_block=16,
                                block_q=8, block_n=16),
        )
        # legacy top@k fields are preserved exactly; each cutoff is also
        # reported under its canonical recall@k alias (same value, one search)
        assert {k: v for k, v in got.items() if k.startswith("top@")} == legacy, (
            impl, got, legacy
        )
        for k in (1, 5, 20):
            assert got[f"recall@{k}"] == got[f"top@{k}"]


def test_eval_search_memory_bounded_by_block():
    """The blocked search must never materialize the (Q, N) score matrix:
    compiled temp bytes stay well under Q*N*4 when block << N."""
    from repro.launch.hlo_analysis import memory_numbers

    qn, n, d, k, block = 64, 8192, 16, 10, 128
    be = DenseSearchBackend(block=block)
    q, p = _rand(qn, n, d)
    compiled = (
        jax.jit(lambda a, b: be.topk(a, b, k))
        .lower(jnp.asarray(q), jnp.asarray(p))
        .compile()
    )
    temp = memory_numbers(compiled).get("temp_size_in_bytes", None)
    if temp is None:
        pytest.skip("memory_analysis unavailable on this backend")
    full = qn * n * 4
    assert temp < full // 2, (temp, full)


def test_evaluate_topk_persistent_retriever_tracks_params():
    """The trainer-hook path: a reused Retriever must re-encode the corpus
    with the *current* params each call (ANCE), never serve a stale index,
    and keep its jitted programs across calls."""
    from repro.evaluation import evaluate_topk

    enc = _mlp_encoder()
    corpus = _VecCorpus()
    p_a = enc.init(jax.random.PRNGKey(0))
    p_b = enc.init(jax.random.PRNGKey(7))
    r = Retriever(enc, p_a, RetrieverConfig(score_block=16))
    got_a = evaluate_topk(enc, p_a, corpus, retriever=r)
    reps_a = np.asarray(r.index.reps)
    jit_tokens = r._search_tokens
    got_b = evaluate_topk(enc, p_b, corpus, retriever=r)
    assert not np.allclose(reps_a, np.asarray(r.index.reps))  # re-encoded
    assert r._search_tokens is jit_tokens                     # no re-trace
    assert got_a == evaluate_topk(enc, p_a, corpus)           # == one-off path
    assert got_b == evaluate_topk(enc, p_b, corpus)


def test_trainer_periodic_eval_hook():
    """TrainerConfig.eval_every wires eval_fn results into the history."""
    from repro.runtime.trainer import Trainer, TrainerConfig

    calls = []

    def eval_fn(state, step):
        calls.append(step)
        return {"top@1": 0.5}

    tr = Trainer(
        TrainerConfig(total_steps=6, eval_every=2, log_every=100),
        lambda s, b: (s + b, {"loss": 1.0}),
        next_batch=lambda i: jnp.asarray(1.0),
        eval_fn=eval_fn,
    )
    _, report = tr.run(jnp.asarray(0.0))
    assert calls == [1, 3, 5]
    evald = [h for h in report.history if "eval/top@1" in h]
    assert len(evald) == 3 and evald[0]["eval/top@1"] == 0.5


def test_trainer_eval_failure_does_not_consume_restart_budget():
    """eval is advisory: a deterministically failing eval_fn must not
    trigger restore-and-replay (which would replay the same healthy step
    into the same eval until max_restarts kills the run)."""
    from repro.runtime.trainer import Trainer, TrainerConfig

    def eval_fn(state, step):
        raise RuntimeError("corpus re-encode OOM")

    tr = Trainer(
        TrainerConfig(total_steps=6, eval_every=2, max_restarts=1,
                      log_every=100),
        lambda s, b: (s + b, {"loss": 1.0}),
        next_batch=lambda i: jnp.asarray(1.0),
        eval_fn=eval_fn,
    )
    state, report = tr.run(jnp.asarray(0.0))
    assert report.restarts == 0 and float(state) == 6.0


def test_evaluate_topk_rejects_retriever_plus_cfg():
    from repro.evaluation import evaluate_topk

    enc = _mlp_encoder()
    params = enc.init(jax.random.PRNGKey(0))
    r = Retriever(enc, params, RetrieverConfig())
    with pytest.raises(ValueError, match="not both"):
        evaluate_topk(enc, params, _VecCorpus(), retriever=r,
                      cfg=RetrieverConfig(search_impl="fused"))


# --------------------------------------------- sharded vs replicated (8 dev)
SHARDED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np

    import sys
    sys.path.insert(0, "tests")
    from test_retrieval import _VecCorpus, _mlp_encoder
    from repro.retrieval import Retriever, RetrieverConfig, make_dp_mesh

    assert jax.device_count() == 8
    enc = _mlp_encoder()
    params = enc.init(jax.random.PRNGKey(0))
    corpus = _VecCorpus(n=93)        # 93 % 8 != 0: exercises row padding
    mesh = make_dp_mesh(8)

    for precision, impl in (("fp32", "dense"), ("bf16_banks", "fused")):
        rcfg = dict(top_k=9, precision=precision, score_block=16,
                    block_q=8, block_n=16, search_impl=impl)
        rep = Retriever(enc, params, RetrieverConfig(**rcfg))
        sh = Retriever(
            enc, params,
            RetrieverConfig(index_layout="sharded", **rcfg), mesh=mesh,
        )
        rep.build_index(corpus.passages)
        sh.build_index(corpus.passages)
        assert sh.index.shards == 8
        assert sh.index.bytes_per_device() * 8 == (
            sh.index.reps.shape[0] * sh.index.reps.shape[1]
            * jnp.dtype(sh.index.reps.dtype).itemsize
        )
        # the store is PLACED sharded: each device persistently holds only
        # its rows/8 block (the 1/D HBM claim), not a full replica that
        # gets resharded per search call
        rows, d = sh.index.reps.shape
        shard_shapes = {s.data.shape for s in sh.index.reps.addressable_shards}
        assert shard_shapes == {(rows // 8, d)}, shard_shapes
        ids_r, s_r = rep.search(corpus.queries[:17])
        ids_s, s_s = sh.search(corpus.queries[:17])
        # sharded must match replicated bit-for-bit: ids AND scores
        np.testing.assert_array_equal(ids_r, ids_s, err_msg=impl)
        np.testing.assert_array_equal(s_r, s_s, err_msg=impl)
        print(f"{precision}/{impl}: OK")
    print("SHARDED-PARITY-OK")
    """
)


@pytest.mark.slow
def test_sharded_index_matches_replicated_8dev():
    res = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "SHARDED-PARITY-OK" in res.stdout


# ----------------------------------------------------- end-to-end smoke
def test_trained_checkpoint_serves_end_to_end(tmp_path):
    """launch/train.py checkpoint -> load_trained_params -> Retriever ->
    BatchingServer -> recall: the full trainer-to-serving round trip at
    tiny scale, including the launch/serve.py --ckpt driver."""
    from repro.launch import serve as serve_mod
    from repro.launch import train as train_mod

    ckpt = str(tmp_path / "ckpt")
    train_mod.main([
        "--steps", "4", "--total-batch", "8", "--local-batch", "4",
        "--bank", "16", "--corpus-size", "64",
        "--checkpoint-dir", ckpt, "--checkpoint-every", "2",
    ])
    params, step = load_trained_params(ckpt)
    assert step == 3
    assert "query" in params and "passage" in params

    stats = serve_mod.main([
        "--ckpt", ckpt, "--n-passages", "64", "--n-queries", "8",
        "--top-k", "8", "--max-batch", "4",
    ])
    assert stats["qps"] > 0
    assert 0.0 <= stats["recall"] <= 1.0
    assert stats["batch_mean"] >= 1.0

    # the loaded params really are the trained ones, not a fresh init
    enc = train_mod.tiny_bert()
    from repro.models.bert import init_bert

    fresh = init_bert(jax.random.PRNGKey(0), enc)
    assert not np.allclose(
        np.asarray(params["query"]["embed"]["word"]),
        np.asarray(fresh["embed"]["word"]),
    )


def test_load_trained_params_rejects_foreign_checkpoint(tmp_path):
    from repro.checkpoint.checkpoint import save_checkpoint

    save_checkpoint(str(tmp_path), 0, {"weights": np.zeros((2,))})
    with pytest.raises(ValueError, match="no 'state/params/'"):
        load_trained_params(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        load_trained_params(str(tmp_path / "nope"))


def test_retriever_requires_index_before_search():
    enc = _mlp_encoder()
    params = enc.init(jax.random.PRNGKey(0))
    r = Retriever(enc, params, RetrieverConfig(top_k=3))
    with pytest.raises(ValueError, match="no index"):
        r.search(np.zeros((2, 12), np.float32))
    with pytest.raises(ValueError, match="no index"):
        make_server(r)


def test_make_server_round_trips_retriever_results():
    enc = _mlp_encoder()
    params = enc.init(jax.random.PRNGKey(0))
    corpus = _VecCorpus(n=40)
    r = Retriever(enc, params, RetrieverConfig(top_k=5, score_block=8))
    r.build_index(corpus.passages)
    direct_ids, direct_scores = r.search(corpus.queries[:6])
    srv = make_server(r, max_batch=6, max_wait_s=0.02).start()
    try:
        futs = [srv.submit(corpus.queries[i]) for i in range(6)]
        for i, f in enumerate(futs):
            ids, scores = f.get(timeout=30)
            np.testing.assert_array_equal(ids, direct_ids[i])
            np.testing.assert_allclose(scores, direct_scores[i], atol=1e-6)
    finally:
        srv.stop()


def test_retrieval_cells_build_and_trace():
    """launch/steps.py serve/eval cells build and trace with sharded index
    SDS inputs (compile cost is covered at MLP scale above)."""
    from jax.sharding import Mesh

    from repro.launch.steps import build_cell

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    for shape, impl in (("serve_topk", "dense"), ("eval_topk", "fused")):
        prog = build_cell("dpr-bert-base", shape, mesh)
        assert prog.static_info["search_impl"] == impl
        assert prog.static_info["index_bytes_per_device"] > 0
        ids, scores = jax.eval_shape(prog.fn, *prog.args)
        assert ids.shape == (prog.static_info["top_k"],) or ids.shape[1] == (
            prog.static_info["top_k"]
        )
        assert scores.dtype == jnp.float32
