"""Exact identities between the paper's four methods (Sec. 3.1-3.2).

These are the correctness foundation of the framework:
  * GradCache must produce *exactly* the full-batch (DPR) gradients.
  * GradAccum must equal the mean of per-chunk losses/grads (Eq. 4).
  * ContAccum with empty banks and K=1 must reduce to DPR.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ContrastiveConfig, init_state, make_update_fn
from repro.core.loss import contrastive_step_loss
from repro.optim import adamw, chain, clip_by_global_norm, sgd

from helpers import make_batch, make_mlp_encoder


def _tx(cfg: ContrastiveConfig):
    # SGD keeps post-update param comparison well-conditioned for the exact
    # identity tests (see optim.sgd docstring); AdamW is exercised elsewhere.
    return chain(clip_by_global_norm(cfg.grad_clip_norm), sgd(0.1))


def _run_one(method, batch, *, k=1, bank=0, n_hard=0, seed=0, **cfg_kw):
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(
        method=method, accumulation_steps=k, bank_size=bank, **cfg_kw
    )
    tx = _tx(cfg)
    state = init_state(jax.random.PRNGKey(seed), enc, tx, cfg)
    update = jax.jit(make_update_fn(enc, tx, cfg))
    new_state, metrics = update(state, batch)
    return state, new_state, metrics


@pytest.mark.parametrize("n_hard", [0, 2])
def test_gradcache_exactly_matches_dpr(n_hard):
    batch = make_batch(jax.random.PRNGKey(1), 16, n_hard=n_hard)
    _, s_dpr, m_dpr = _run_one("dpr", batch, n_hard=n_hard)
    _, s_gc, m_gc = _run_one("grad_cache", batch, k=4, n_hard=n_hard)
    np.testing.assert_allclose(m_dpr.loss, m_gc.loss, rtol=1e-6)
    for a, b in zip(
        jax.tree_util.tree_leaves(s_dpr.params), jax.tree_util.tree_leaves(s_gc.params)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-7)
    np.testing.assert_allclose(m_dpr.grad_norm, m_gc.grad_norm, rtol=1e-5)


def test_gradaccum_equals_eq4_manual():
    """GradAccum loss/grads == mean over chunk-restricted InfoNCE (Eq. 4)."""
    enc = make_mlp_encoder()
    batch = make_batch(jax.random.PRNGKey(2), 12, n_hard=1)
    k = 3
    cfg = ContrastiveConfig(method="grad_accum", accumulation_steps=k)
    tx = _tx(cfg)
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    _, metrics = jax.jit(make_update_fn(enc, tx, cfg))(state, batch)

    # manual Eq. 4
    def chunk_loss(params, lo, hi):
        q = enc.encode_query(params, batch.query[lo:hi])
        pp = enc.encode_passage(params, batch.passage_pos[lo:hi])
        ph = enc.encode_passage(
            params, batch.passage_hard[lo:hi].reshape(-1, batch.passage_hard.shape[-1])
        )
        loss, _ = contrastive_step_loss(q, pp, ph, None, None)
        return loss

    losses = [chunk_loss(state.params, i * 4, (i + 1) * 4) for i in range(k)]
    np.testing.assert_allclose(metrics.loss, np.mean([float(l) for l in losses]), rtol=1e-6)

    grads = [jax.grad(chunk_loss)(state.params, i * 4, (i + 1) * 4) for i in range(k)]
    mean_grads = jax.tree_util.tree_map(lambda *g: sum(g) / k, *grads)
    # compare grad_norm metric against the manual mean-of-chunk-grads
    # (metrics report pre-clip norms; the ratio is invariant to global clip)
    from repro.common.treemath import tree_global_norm

    manual = float(tree_global_norm(mean_grads))
    np.testing.assert_allclose(float(metrics.grad_norm), manual, rtol=1e-5)


def test_gradaccum_uses_fewer_negatives_than_dpr():
    batch = make_batch(jax.random.PRNGKey(3), 16)
    _, _, m_dpr = _run_one("dpr", batch)
    _, _, m_ga = _run_one("grad_accum", batch, k=4)
    assert float(m_dpr.n_negatives) == 15.0
    assert float(m_ga.n_negatives) == 3.0  # N_local - 1


def test_contaccum_reduces_to_dpr_when_no_bank():
    batch = make_batch(jax.random.PRNGKey(4), 8)
    _, s_dpr, m_dpr = _run_one("dpr", batch)
    _, s_ca, m_ca = _run_one("contaccum", batch, k=1, bank=0)
    np.testing.assert_allclose(m_dpr.loss, m_ca.loss, rtol=1e-6)
    for a, b in zip(
        jax.tree_util.tree_leaves(s_dpr.params), jax.tree_util.tree_leaves(s_ca.params)
    ):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-8)


def test_contaccum_negative_count_exceeds_total_batch():
    """Paper Sec. 3.2: if N_mem > N_local*(K-1), ContAccum uses MORE negatives
    than the full total batch."""
    batch = make_batch(jax.random.PRNGKey(5), 16)
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(method="contaccum", accumulation_steps=4, bank_size=32)
    tx = _tx(cfg)
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    update = jax.jit(make_update_fn(enc, tx, cfg))
    # warm the banks: after 3 updates the 32-slot banks are full
    for i in range(3):
        key = jax.random.PRNGKey(10 + i)
        state, metrics = update(state, make_batch(key, 16))
    state, metrics = update(state, make_batch(jax.random.PRNGKey(99), 16))
    # columns = N_local + N_mem = 4 + 32 -> 35 negatives > N_total - 1 = 15
    assert float(metrics.n_negatives) == 35.0
    assert float(metrics.bank_fill_q) == 32.0
    assert float(metrics.bank_fill_p) == 32.0


def test_contaccum_bank_warmup_is_exact():
    """With a half-filled bank, the loss must equal an explicit small-matrix
    computation using only the valid entries."""
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(method="contaccum", accumulation_steps=1, bank_size=8)
    tx = _tx(cfg)
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    update = jax.jit(make_update_fn(enc, tx, cfg))
    b1 = make_batch(jax.random.PRNGKey(11), 4)
    b2 = make_batch(jax.random.PRNGKey(12), 4)
    params0 = state.params  # bank reps are encoded with the PRE-update params
    state, _ = update(state, b1)  # bank now holds 4 of 8
    params = state.params

    q2 = enc.encode_query(params, b2.query)
    p2 = enc.encode_passage(params, b2.passage_pos)
    # 'past encoder' semantics: the bank holds representations produced by the
    # encoder as it was when b1 was processed
    q1 = enc.encode_query(params0, b1.query)
    p1 = enc.encode_passage(params0, b1.passage_pos)

    # explicit extended matrix: rows [q2; q1], cols [p2; p1]
    q_all = jnp.concatenate([q2, q1])
    p_all = jnp.concatenate([p2, p1])
    logits = q_all @ p_all.T
    lse = jax.nn.logsumexp(logits, axis=-1)
    pos = jnp.diag(logits)
    expected = float(jnp.mean(lse - pos))

    _, metrics = update(state, b2)
    np.testing.assert_allclose(float(metrics.loss), expected, rtol=1e-5)


def test_reset_banks_ablation():
    """'w/o past encoder': banks cleared each update -> after an update with
    K=2, banks contain only this update's 2 chunks."""
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(
        method="contaccum",
        accumulation_steps=2,
        bank_size=64,
        reset_banks_each_update=True,
    )
    tx = _tx(cfg)
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    update = jax.jit(make_update_fn(enc, tx, cfg))
    for i in range(3):
        state, metrics = update(state, make_batch(jax.random.PRNGKey(i), 8))
    assert float(metrics.bank_fill_q) == 8.0  # 2 chunks x 4, not 24


def test_query_bank_ablation_pre_batch_negatives():
    """'w/o M_q' (pre-batch negatives): passage bank only."""
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(
        method="contaccum", accumulation_steps=2, bank_size=16, use_query_bank=False
    )
    nq, np_ = cfg.resolved_bank_sizes()
    assert nq == 0 and np_ == 16
    tx = _tx(cfg)
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    update = jax.jit(make_update_fn(enc, tx, cfg))
    for i in range(3):
        state, metrics = update(state, make_batch(jax.random.PRNGKey(i), 8))
    assert float(metrics.bank_fill_p) == 16.0
    assert float(metrics.bank_fill_q) == 0.0
    # negatives still extended by the passage bank
    assert float(metrics.n_negatives) == 4 + 16 - 1


def test_loss_decreases_over_training():
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(method="contaccum", accumulation_steps=2, bank_size=16)
    tx = chain(clip_by_global_norm(2.0), adamw(1e-2))
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    update = jax.jit(make_update_fn(enc, tx, cfg))
    first = last = None
    for i in range(30):
        state, metrics = update(state, make_batch(jax.random.PRNGKey(i % 5), 8))
        if first is None:
            first = float(metrics.loss)
        last = float(metrics.loss)
    assert last < first


def test_all_methods_finite_and_jittable():
    batch = make_batch(jax.random.PRNGKey(7), 8, n_hard=1)
    for method, kw in [
        ("dpr", {}),
        ("grad_accum", dict(k=2)),
        ("grad_cache", dict(k=2)),
        ("contaccum", dict(k=2, bank=8)),
    ]:
        _, s, m = _run_one(method, batch, n_hard=1, **kw)
        assert np.isfinite(float(m.loss)), method
        for leaf in jax.tree_util.tree_leaves(s.params):
            assert np.all(np.isfinite(np.asarray(leaf))), method
