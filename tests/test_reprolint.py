"""reprolint: one known-good + one seeded-violation fixture per rule,
suppression/whitelist mechanics, and a smoke run over the real tree.

Fixtures are written to tmp_path so every assertion is about exact rule IDs
and line numbers — the linter's contract is *where* it fires, not just that
it fires.
"""

import sys
import textwrap
from pathlib import Path


REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.reprolint import run_reprolint  # noqa: E402
from tools.reprolint.whitelist import WhitelistEntry  # noqa: E402


def lint(tmp_path, files, *, rules=None, whitelist=(), axes=("data", "model")):
    """Write ``files`` (relpath -> source) under tmp_path and lint them all."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    (tmp_path / "ROADMAP.md").touch()  # root marker for relpath computation
    return run_reprolint(
        [str(tmp_path)],
        root=str(tmp_path),
        tests_dir=str(tmp_path / "tests"),
        extra_axes=list(axes),
        whitelist=list(whitelist),
        rules=rules,
    )


def only(result, rule):
    assert all(v.rule == rule for v in result.violations), result.format()
    return result.violations


# ---------------------------------------------------------------- RPL001


def test_dtype_literal_fires_with_line(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/box.py": """\
            import jax.numpy as jnp


            def f(x):
                y = x.astype(jnp.bfloat16)
                return jnp.zeros((3,), dtype="float32") + y
            """
        },
        rules=["RPL001"],
    )
    vs = only(res, "RPL001")
    assert [(v.line, v.get("dtype")) for v in vs] == [
        (5, "bfloat16"),
        (6, "float32"),
    ]


def test_dtype_literal_good(tmp_path):
    res = lint(
        tmp_path,
        {
            # the owner module may spell dtypes; elsewhere the fp32
            # accumulation pin and policy-routed dtypes are clean
            "src/core/precision.py": """\
            import jax.numpy as jnp

            STATS_DTYPE = jnp.float32
            """,
            "src/ok.py": """\
            import jax.numpy as jnp
            from core.precision import STATS_DTYPE


            def f(a, b, policy):
                acc = jnp.einsum("md,nd->mn", a, b, preferred_element_type=jnp.float32)
                return acc.astype(STATS_DTYPE), a.astype(policy.compute_dtype)
            """,
        },
        rules=["RPL001"],
    )
    assert res.ok, res.format()


# ---------------------------------------------------------------- RPL002


def test_collective_axis_fires_with_line(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/mesh.py": """\
            import jax

            mesh = jax.make_mesh((1, 1), ("data", "model"))
            """,
            "src/coll.py": """\
            import jax


            def f(x):
                y = jax.lax.psum(x, "dp")
                return jax.lax.all_gather(y, axis_name="rows")
            """,
        },
        rules=["RPL002"],
        axes=(),
    )
    vs = only(res, "RPL002")
    assert [(v.line, v.get("axis")) for v in vs] == [(5, "dp"), (6, "rows")]


def test_collective_axis_good(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/mesh.py": """\
            import jax

            mesh = jax.make_mesh((1, 1), ("data", "model"))
            """,
            "src/coll.py": """\
            import jax
            from jax.sharding import PartitionSpec as P


            def f(x, axis):
                spec = P("data", "model")
                return jax.lax.psum(x, axis), jax.lax.pmean(x, "data"), spec
            """,
        },
        rules=["RPL002"],
        axes=(),
    )
    assert res.ok, res.format()


_RING_MESH = {
    "src/mesh.py": """\
    import jax

    mesh = jax.make_mesh((4,), ("data",))
    """
}


def test_ppermute_perm_fires_with_line(tmp_path):
    res = lint(
        tmp_path,
        {
            **_RING_MESH,
            "src/ring.py": """\
            import jax


            def f(x):
                a = jax.lax.ppermute(x, "data", perm=[(0, 1), (0, 2), (1, 0), (2, 0)])
                b = jax.lax.ppermute(x, "data", perm=[(0, 2), (2, 4), (4, 0)])
                c = jax.lax.ppermute(x, "data", perm=[(0, 1), (1, 0), (2, 3), (3, 2)])
                d = jax.lax.ppermute(x, "data", perm=[(0, 1), (1, 0)])
                return a, b, c, d
            """,
        },
        rules=["RPL002"],
        axes=(),
    )
    vs = only(res, "RPL002")
    assert all(v.get("check") == "ppermute_perm" for v in vs), res.format()
    assert [v.line for v in vs] == [5, 6, 7, 8]
    assert "repeats a source" in vs[0].message
    assert "contiguous range 0..2" in vs[1].message
    assert "not a single complete cycle" in vs[2].message
    assert "declared with size 4" in vs[3].message


def test_ppermute_perm_good(tmp_path):
    res = lint(
        tmp_path,
        {
            **_RING_MESH,
            "src/ring.py": """\
            import jax


            def rotate(x, d):
                # computed tables (DistCtx.ring_perm style) are runtime facts
                perm = [(i, (i + 1) % d) for i in range(d)]
                full = jax.lax.ppermute(x, "data", perm=[(0, 1), (1, 2), (2, 3), (3, 0)])
                return jax.lax.ppermute(x, "data", perm=perm), full
            """,
        },
        rules=["RPL002"],
        axes=(),
    )
    assert res.ok, res.format()


# ---------------------------------------------------------------- RPL003

_KERNEL_OK = {
    "src/kernels/addone/addone.py": """\
    from jax.experimental import pallas as pl


    def _kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] + 1


    def addone(x):
        return pl.pallas_call(_kernel, out_shape=x)(x)
    """,
    "src/kernels/addone/ref.py": """\
    def addone_ref(x):
        return x + 1
    """,
    "tests/test_addone.py": """\
    # parity test for addone kernel-vs-ref
    """,
}


def test_pallas_registry_good(tmp_path):
    res = lint(tmp_path, dict(_KERNEL_OK), rules=["RPL003"])
    assert res.ok, res.format()


def test_pallas_registry_fires_outside_registry(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/stray.py": """\
            from jax.experimental import pallas as pl


            def f(x):
                return pl.pallas_call(lambda x_ref, o_ref: None, out_shape=x)(x)
            """
        },
        rules=["RPL003"],
    )
    vs = only(res, "RPL003")
    assert [v.line for v in vs] == [5]
    assert "outside the kernel registry" in vs[0].message


def test_pallas_registry_fires_missing_ref_and_test(tmp_path):
    files = {k: v for k, v in _KERNEL_OK.items() if "ref.py" not in k}
    files["tests/test_addone.py"] = "# no kernel name mentioned here\n"
    res = lint(tmp_path, files, rules=["RPL003"])
    vs = only(res, "RPL003")
    msgs = "\n".join(v.message for v in vs)
    assert "no ref.py" in msgs and "parity test" in msgs


# ---------------------------------------------------------------- RPL004


def test_pallas_closure_fires_with_line(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/kernels/scaled/scaled.py": """\
            from jax.experimental import pallas as pl


            def build(x, scale: float):
                def _kernel(x_ref, o_ref):
                    o_ref[...] = x_ref[...] * scale
                return pl.pallas_call(_kernel, out_shape=x)(x)
            """
        },
        rules=["RPL004"],
    )
    vs = only(res, "RPL004")
    assert [(v.line, v.get("name")) for v in vs] == [(6, "scale")]


def test_pallas_closure_good_partial_binding(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/kernels/scaled/scaled.py": """\
            from functools import partial

            from jax.experimental import pallas as pl


            def _kernel(x_ref, o_ref, *, scale):
                o_ref[...] = x_ref[...] * scale


            def build(x, scale: float):
                return pl.pallas_call(partial(_kernel, scale=scale), out_shape=x)(x)
            """
        },
        rules=["RPL004"],
    )
    assert res.ok, res.format()


# ---------------------------------------------------------------- RPL005


def test_jit_hazard_fires_with_line(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/jitted.py": """\
            import jax


            @jax.jit
            def f(x):
                if x > 0:
                    print("positive", x)
                return x


            def g(y):
                while y.sum() > 1:
                    y = y * 0.5
                return y


            g_fast = jax.jit(g)
            """
        },
        rules=["RPL005"],
    )
    vs = only(res, "RPL005")
    assert [v.line for v in vs] == [6, 7, 12]
    assert "lax.cond" in vs[0].message
    assert "trace time" in vs[1].message


def test_jit_hazard_good_static_and_shape(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/jitted.py": """\
            from functools import partial

            import jax


            @partial(jax.jit, static_argnames=("n",))
            def f(x, n):
                if n > 4:
                    x = x + 1
                if x.shape[0] > 2:
                    x = x * 2
                if x is None:
                    return 0
                return x
            """
        },
        rules=["RPL005"],
    )
    assert res.ok, res.format()


def test_jit_hazard_mining_refresh_fires_with_line(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/jitted.py": """\
            import jax


            @jax.jit
            def f(state, miner):
                miner.refresh_async(state.params, state.step)
                return state


            def g(state, self):
                self.miner.refresh(state.params, 0)
                return state


            g_fast = jax.jit(g)
            """
        },
        rules=["RPL005"],
    )
    vs = only(res, "RPL005")
    assert [v.line for v in vs] == [6, 11]
    assert "mining refresh entry point" in vs[0].message
    assert "PeriodicHook" in vs[0].message


def test_jit_hazard_mining_refresh_good(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/jitted.py": """\
            import jax


            @jax.jit
            def f(x, cache):
                cache.refresh()  # no miner/mining in the owner chain
                return x


            def hook(state, step, miner):  # not jitted: the intended path
                miner.refresh_async(state.params, step)
            """
        },
        rules=["RPL005"],
    )
    assert res.ok, res.format()


# ---------------------------------------------------------------- RPL006


def test_stats_dtype_fires_with_line(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/stats.py": """\
            import jax.numpy as jnp


            def metrics(x, policy):
                loss = jnp.mean(x.astype(policy.compute_dtype))
                acc = jnp.sum(x.astype(jnp.bfloat16)) / x.shape[0]
                return loss, acc
            """
        },
        rules=["RPL006"],
    )
    vs = only(res, "RPL006")
    assert [(v.line, v.get("stat")) for v in vs] == [(5, "loss"), (6, "acc")]


def test_stats_dtype_good(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/stats.py": """\
            import jax.numpy as jnp

            STATS_DTYPE = jnp.float32


            def metrics(x, y, policy):
                loss = jnp.mean(x.astype(STATS_DTYPE))
                hidden = jnp.mean(y.astype(policy.compute_dtype))  # not a stat
                return loss, hidden
            """
        },
        rules=["RPL006"],
    )
    assert res.ok, res.format()


# ------------------------------------------------------- suppressions


def test_inline_suppression(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/box.py": """\
            import jax.numpy as jnp

            A = jnp.zeros((3,), jnp.bfloat16)  # reprolint: disable=RPL001
            B = jnp.zeros((3,), jnp.bfloat16)
            """
        },
        rules=["RPL001"],
    )
    assert [v.line for v in res.violations] == [4]
    assert res.suppressed == 1


def test_file_suppression_only_in_header(tmp_path):
    res = lint(
        tmp_path,
        {
            "src/box.py": """\
            # reprolint: disable-file=RPL001
            import jax.numpy as jnp

            A = jnp.zeros((3,), jnp.bfloat16)
            """
        },
        rules=["RPL001"],
    )
    assert res.ok and res.suppressed == 1
    # the same pragma past the header window is inert
    res2 = lint(
        tmp_path,
        {
            "src/late.py": "\n" * 20
            + textwrap.dedent(
                """\
                # reprolint: disable-file=RPL001
                import jax.numpy as jnp

                A = jnp.zeros((3,), jnp.bfloat16)
                """
            )
        },
        rules=["RPL001"],
    )
    assert not res2.ok


# ---------------------------------------------------------- whitelist


def test_whitelist_is_dtype_scoped(tmp_path):
    files = {
        "src/opt.py": """\
        import jax.numpy as jnp

        M = jnp.zeros((3,), jnp.float32)
        V = jnp.zeros((3,), jnp.bfloat16)
        """
    }
    entry = WhitelistEntry(
        pattern="src/opt.py",
        rules=("RPL001",),
        reason="fp32 masters",
        dtypes=frozenset({"float32"}),
    )
    res = lint(tmp_path, files, rules=["RPL001"], whitelist=[entry])
    # fp32 absorbed by the entry; the bf16 literal still fails
    assert [(v.line, v.get("dtype")) for v in res.violations] == [(4, "bfloat16")]
    assert res.whitelisted == 1


# ---------------------------------------------------------- real tree


def test_real_tree_is_clean():
    res = run_reprolint(
        [str(REPO_ROOT / "src"), str(REPO_ROOT / "tools")],
        root=str(REPO_ROOT),
        tests_dir=str(REPO_ROOT / "tests"),
    )
    assert res.ok, res.format()


def test_real_tree_mesh_axes_are_discovered():
    # the declared axes come from launch/mesh.py + debug meshes; if this
    # breaks, RPL002 has lost its ground truth and every axis would flag
    res = run_reprolint(
        [str(REPO_ROOT / "src")],
        root=str(REPO_ROOT),
        tests_dir=str(REPO_ROOT / "tests"),
        rules=["RPL002"],
    )
    assert res.ok, res.format()
