"""Model-zoo unit tests: shapes, finiteness, numerics identities
(chunked attention == plain attention; prefill+decode == teacher forcing)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunked_attention, plain_attention
from repro.models.bert import BertConfig, bert_encode, init_bert
from repro.models.gnn import GraphBatch, SchNetConfig, init_schnet, schnet_loss
from repro.models.lm import (
    LMConfig,
    decode_step,
    init_lm,
    lm_loss,
    prefill,
)
from repro.models.moe import MoEConfig
from repro.models.recsys import (
    RecsysConfig,
    bce_loss,
    forward,
    init_recsys,
    score_candidates,
)


TINY_LM = LMConfig(
    name="tiny",
    n_layers=2,
    d_model=32,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=128,
    dtype=jnp.float32,
    q_chunk=8,
    kv_chunk=8,
    loss_chunk=8,
    remat="none",
)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("seed", range(3))
def test_chunked_attention_matches_plain(causal, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    b, sq, skv, h, hk, d = 2, 16, 32, 4, 2, 8
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, skv, hk, d))
    v = jax.random.normal(ks[2], (b, skv, hk, d))
    mask = jax.random.bernoulli(ks[3], 0.8, (b, skv))
    mask = mask.at[:, 0].set(True)
    if causal:
        sq2 = skv  # causal requires aligned positions
        q = jax.random.normal(ks[0], (b, sq2, h, d))
        out_p = plain_attention(q, k, v, causal=True)
        out_c = chunked_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    else:
        out_p = plain_attention(q, k, v, kv_mask=mask)
        out_c = chunked_attention(q, k, v, kv_mask=mask, q_chunk=8, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_c), rtol=2e-5, atol=2e-6)


def test_lm_train_loss_finite_and_decreasing_direction():
    params = init_lm(jax.random.PRNGKey(0), TINY_LM)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    targets = jnp.roll(tokens, -1, axis=1)
    loss, aux = jax.jit(lambda p: lm_loss(p, TINY_LM, tokens, targets))(params)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: lm_loss(p, TINY_LM, tokens, targets)[0])(params)
    assert all(np.all(np.isfinite(np.asarray(x))) for x in jax.tree_util.tree_leaves(g))


def test_lm_chunked_loss_matches_dense_xent():
    params = init_lm(jax.random.PRNGKey(0), TINY_LM)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    targets = jnp.roll(tokens, -1, axis=1)
    targets = targets.at[:, -1].set(-1)  # mask the wrap position
    loss, _ = lm_loss(params, TINY_LM, tokens, targets)

    from repro.models.lm import backbone, _head

    x, _, _ = backbone(params, TINY_LM, tokens)
    logits = _head(params, TINY_LM, x).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, -1)
    pos = jnp.take_along_axis(logits, jnp.maximum(targets, 0)[..., None], -1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    dense = ((lse - pos) * mask).sum() / mask.sum()
    np.testing.assert_allclose(float(loss), float(dense), rtol=1e-6)


def test_prefill_decode_matches_teacher_forcing():
    """Greedy decode logits must match full-sequence forward logits."""
    cfg = TINY_LM
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)

    from repro.models.lm import backbone, _head

    x, _, _ = backbone(params, cfg, tokens)
    full_logits = _head(params, cfg, x)  # (B, S, V)

    cache, logits_p = prefill(params, cfg, tokens[:, :4], max_seq=16)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, 3]), rtol=2e-4, atol=2e-5
    )
    # decode positions 4..7 one token at a time
    logits_d = logits_p
    for t in range(4, 8):
        cache, logits_d = decode_step(params, cfg, cache, tokens[:, t])
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, t]), rtol=2e-4, atol=2e-5
        )


def test_moe_lm_forward_and_grads():
    cfg = LMConfig(
        name="tiny-moe",
        n_layers=2,
        d_model=32,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=64,
        dtype=jnp.float32,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=16, group_size=16),
        loss_chunk=8,
        remat="none",
    )
    params = init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)
    targets = jnp.roll(tokens, -1, 1)
    loss, aux = jax.jit(lambda p: lm_loss(p, cfg, tokens, targets))(params)
    assert np.isfinite(float(loss))
    assert float(aux["moe_aux"]) >= 0.0
    g = jax.grad(lambda p: lm_loss(p, cfg, tokens, targets)[0])(params)
    # router must receive gradient
    assert float(jnp.abs(g["layers"]["ffn"]["router"]).sum()) > 0


def test_moe_all_experts_used_capacity():
    """With uniform tokens and enough capacity no tokens are dropped."""
    from repro.models.moe import moe_ffn

    cfg = MoEConfig(n_experts=4, top_k=1, d_expert=16, capacity_factor=4.0, group_size=32)
    rng = jax.random.PRNGKey(0)
    from repro.models.moe import init_moe

    params = jax.tree_util.tree_map(lambda x: x[0], init_moe(rng, 8, cfg, 1))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    y, metrics = moe_ffn(params, x, cfg)
    assert y.shape == (32, 8)
    assert float(metrics["moe_dropped_frac"]) == 0.0


def test_bert_encode_shapes_and_mask_effect():
    cfg = BertConfig(n_layers=2, d_model=32, n_heads=4, d_ff=64, vocab_size=100,
                     max_position=32)
    params = init_bert(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (3, 10), 0, 100)
    mask = jnp.ones((3, 10), bool).at[1, 5:].set(False)
    reps = bert_encode(params, cfg, tokens, mask)
    assert reps.shape == (3, 32)
    # masked tail must not influence the [CLS] representation
    tokens2 = tokens.at[1, 5:].set(7)
    reps2 = bert_encode(params, cfg, tokens2, mask)
    np.testing.assert_allclose(np.asarray(reps[1]), np.asarray(reps2[1]), rtol=1e-5, atol=1e-6)


def test_schnet_molecule_energy():
    cfg = SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=20)
    params = init_schnet(jax.random.PRNGKey(0), cfg)
    n, e, g_count = 12, 24, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    batch = GraphBatch(
        nodes=jax.random.randint(ks[0], (n,), 1, 10),
        src=jax.random.randint(ks[1], (e,), 0, n),
        dst=jax.random.randint(ks[2], (e,), 0, n),
        edge_dist=jax.random.uniform(ks[3], (e,), minval=0.5, maxval=9.0),
        node_mask=jnp.ones((n,), bool),
        edge_mask=jnp.ones((e,), bool),
        graph_id=jnp.repeat(jnp.arange(g_count), n // g_count),
        n_graphs=g_count,
        targets=jnp.array([1.0, -1.0, 0.5]),
    )
    loss, aux = jax.jit(lambda p: schnet_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: schnet_loss(p, cfg, batch)[0])(params)
    assert all(np.all(np.isfinite(np.asarray(x))) for x in jax.tree_util.tree_leaves(g))


def test_schnet_mse_loss_reduces_in_fp32():
    """fp32-stats contract: the MSE statistic must reduce in fp32 even when
    both the energy prediction and the targets arrive in bf16 (regression —
    the loss used to inherit bf16 from its operands)."""
    cfg = SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=20, dtype=jnp.bfloat16)
    params = init_schnet(jax.random.PRNGKey(0), cfg)
    n, e, g_count = 12, 24, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    batch = GraphBatch(
        nodes=jax.random.randint(ks[0], (n,), 1, 10),
        src=jax.random.randint(ks[1], (e,), 0, n),
        dst=jax.random.randint(ks[2], (e,), 0, n),
        edge_dist=jax.random.uniform(ks[3], (e,), minval=0.5, maxval=9.0),
        node_mask=jnp.ones((n,), bool),
        edge_mask=jnp.ones((e,), bool),
        graph_id=jnp.repeat(jnp.arange(g_count), n // g_count),
        n_graphs=g_count,
        targets=jnp.array([1.0, -1.0, 0.5], jnp.bfloat16),
    )
    loss, aux = jax.jit(lambda p: schnet_loss(p, cfg, batch))(params)
    assert loss.dtype == jnp.float32
    assert aux["mse"].dtype == jnp.float32
    # and the value matches an fp32 reduction of the same bf16 inputs exactly
    from repro.models.gnn import schnet_energy

    pred = np.asarray(schnet_energy(params, cfg, batch), np.float32)
    tgt = np.asarray(batch.targets, np.float32)
    np.testing.assert_allclose(float(loss), np.mean((pred - tgt) ** 2), rtol=1e-6)


def test_schnet_node_classification_with_mask():
    cfg = SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=20, d_feat=8, n_classes=5)
    params = init_schnet(jax.random.PRNGKey(0), cfg)
    n, e = 20, 50
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    batch = GraphBatch(
        nodes=jax.random.normal(ks[0], (n, 8)),
        src=jax.random.randint(ks[1], (e,), 0, n),
        dst=jax.random.randint(ks[2], (e,), 0, n),
        edge_dist=jax.random.uniform(ks[3], (e,), minval=0.5, maxval=9.0),
        node_mask=jnp.ones((n,), bool),
        edge_mask=jnp.ones((e,), bool),
        targets=jax.random.randint(ks[4], (n,), 0, 5),
        target_mask=jnp.arange(n) < 10,
    )
    loss, aux = schnet_loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(aux["accuracy"]) <= 1.0


RECSYS_CASES = [
    RecsysConfig(
        name="dlrm-ut", n_dense=4, vocab_sizes=(50, 30, 20), embed_dim=8,
        interaction="dot", bot_mlp=(16, 8), top_mlp=(16, 8, 1),
    ),
    RecsysConfig(
        name="dcn-ut", n_dense=4, vocab_sizes=(50, 30, 20), embed_dim=8,
        interaction="cross", n_cross_layers=2, top_mlp=(16, 8),
    ),
    RecsysConfig(
        name="deepfm-ut", n_dense=0, vocab_sizes=(50, 30, 20, 10), embed_dim=6,
        interaction="fm", top_mlp=(16, 16),
    ),
]


@pytest.mark.parametrize("cfg", RECSYS_CASES, ids=lambda c: c.name)
def test_recsys_forward_and_loss(cfg):
    params = init_recsys(jax.random.PRNGKey(0), cfg)
    b = 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    dense = jax.random.normal(ks[0], (b, cfg.n_dense)) if cfg.n_dense else jnp.zeros((b, 0))
    sparse = jnp.stack(
        [jax.random.randint(ks[1], (b,), 0, v) for v in cfg.vocab_sizes], axis=1
    )
    labels = jax.random.bernoulli(ks[2], 0.3, (b,))
    logits = forward(params, cfg, dense, sparse)
    assert logits.shape == (b,)
    loss, aux = jax.jit(lambda p: bce_loss(p, cfg, dense, sparse, labels))(params)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: bce_loss(p, cfg, dense, sparse, labels)[0])(params)
    assert float(jnp.abs(g["table"]).sum()) > 0


@pytest.mark.parametrize("cfg", RECSYS_CASES, ids=lambda c: c.name)
def test_score_candidates_matches_forward(cfg):
    """Factorized candidate scoring == full forward with the swapped field."""
    params = init_recsys(jax.random.PRNGKey(0), cfg)
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    dense = jax.random.normal(ks[0], (1, cfg.n_dense)) if cfg.n_dense else jnp.zeros((1, 0))
    sparse = jnp.array([[3] + [1] * (cfg.n_sparse - 1)], jnp.int32)
    cands = jnp.arange(10, dtype=jnp.int32)
    fast = score_candidates(params, cfg, dense, sparse, cands)
    # reference: full forward with field 0 replaced per candidate
    sp = jnp.tile(sparse, (10, 1)).at[:, 0].set(cands)
    dn = jnp.tile(dense, (10, 1))
    ref = forward(params, cfg, dn, sp)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref), rtol=2e-5, atol=1e-6)


def test_embedding_bag_mean_pooling():
    from repro.models.recsys import embedding_bag

    cfg = RECSYS_CASES[0]
    params = init_recsys(jax.random.PRNGKey(0), cfg)
    mh = jnp.array([[[1, 2, 0], [4, 0, 0], [3, 3, 3]]], jnp.int32)  # (1, 3, 3)
    lengths = jnp.array([[2, 1, 3]], jnp.int32)
    out = embedding_bag(params, cfg, mh, lengths)
    assert out.shape == (1, 3, cfg.embed_dim)
    # bag 1 with length 1 == plain lookup
    single = embedding_lookup_row = jnp.take(
        params["table"], 4 + cfg.field_offsets()[1], axis=0
    )
    np.testing.assert_allclose(np.asarray(out[0, 1]), np.asarray(single), rtol=1e-6)
