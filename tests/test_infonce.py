"""InfoNCE primitive + agreement between the reference extended loss
(core.infonce.extended_loss) and the production loss (core.loss).

Property-style invariants use seeded randomized sweeps (`hypothesis` is not
installed in this offline container — see DESIGN.md §5).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.infonce import extended_loss, in_batch_loss, info_nce
from repro.core.loss import contrastive_step_loss
from repro.core.memory_bank import init_bank, push


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


def test_info_nce_matches_manual_softmax_xent():
    q = _rand(0, 6, 8)
    p = _rand(1, 6, 8)
    out = info_nce(q, p)
    logits = np.asarray(q @ p.T, dtype=np.float64)
    expected = np.mean(
        [-logits[i, i] + np.log(np.exp(logits[i]).sum()) for i in range(6)]
    )
    np.testing.assert_allclose(float(out.loss), expected, rtol=1e-5)


def test_temperature_scaling():
    q = _rand(2, 4, 8)
    p = _rand(3, 4, 8)
    hot = info_nce(q, p, temperature=0.1)
    cold = info_nce(q, p, temperature=10.0)
    # cold temperature -> logits shrink -> loss approaches log N
    np.testing.assert_allclose(float(cold.loss), np.log(4.0), atol=0.2)
    assert not np.isclose(float(hot.loss), float(cold.loss))


def test_col_mask_excludes_columns_exactly():
    q = _rand(4, 4, 8)
    p = _rand(5, 6, 8)
    mask = jnp.array([True, True, True, True, False, False])
    masked = info_nce(q, p, col_mask=mask)
    dense = info_nce(q, p[:4])
    np.testing.assert_allclose(float(masked.loss), float(dense.loss), rtol=1e-6)


def test_row_mask_excludes_rows_exactly():
    q = _rand(6, 6, 8)
    p = _rand(7, 6, 8)
    labels = jnp.arange(6, dtype=jnp.int32)
    mask = jnp.array([True, True, True, False, False, False])
    masked = info_nce(q, p, labels=labels, row_mask=mask)
    dense = info_nce(q[:3], p, labels=labels[:3])
    np.testing.assert_allclose(float(masked.loss), float(dense.loss), rtol=1e-6)


def test_hard_negatives_increase_loss():
    q = _rand(8, 8, 16)
    p = q + 0.01 * _rand(9, 8, 16)  # near-perfect positives
    hard = q + 0.05 * _rand(10, 8, 16)  # very hard negatives
    plain = in_batch_loss(q, p)
    with_hard = in_batch_loss(q, p, hard)
    assert float(with_hard.loss) > float(plain.loss)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("cq,cp", [(8, 8), (0, 8), (8, 0), (0, 0)])
def test_production_loss_matches_reference(seed, cq, cp):
    """core.loss.contrastive_step_loss ≡ core.infonce.extended_loss across
    bank configurations and fill levels (randomized sweep). Unequal non-zero
    (cq, cp) pairs are deliberately absent: their prefix alignment was only
    sound before a ring wrap, and the production path now rejects them
    (tests/test_memory_bank.py, tests/test_step_program.py)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    b, d, h = 4, 8, 2
    q = jax.random.normal(ks[0], (b, d))
    pp = jax.random.normal(ks[1], (b, d))
    ph = jax.random.normal(ks[2], (b * h, d))

    bank_q = init_bank(cq, d)
    bank_p = init_bank(cp, d)
    n_fill = int(jax.random.randint(ks[3], (), 0, max(min(cq, cp), 1) + 1))
    if n_fill:
        bank_q = push(bank_q, jax.random.normal(ks[4], (n_fill, d)))
        bank_p = push(bank_p, jax.random.normal(ks[5], (n_fill, d)))

    loss_prod, aux = contrastive_step_loss(q, pp, ph, bank_q, bank_p, temperature=0.5)
    ref = extended_loss(
        q,
        pp,
        ph,
        bank_q.buf if cq else None,
        bank_q.valid if cq else None,
        bank_p.buf if cp else None,
        bank_p.valid if cp else None,
        temperature=0.5,
    )
    np.testing.assert_allclose(float(loss_prod), float(ref.loss), rtol=1e-5)


@pytest.mark.parametrize("seed", range(3))
def test_loss_invariant_to_bank_ring_position(seed):
    """The extended loss must not depend on where the ring head is — only on
    the (aligned) contents."""
    d, b = 8, 4
    key = jax.random.PRNGKey(100 + seed)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, d))
    pp = jax.random.normal(ks[1], (b, d))
    qb = jax.random.normal(ks[2], (6, d))
    pb = jax.random.normal(ks[3], (6, d))

    losses = []
    for lead in range(3):
        bank_q = init_bank(6, d)
        bank_p = init_bank(6, d)
        # rotate push order; alignment q_i <-> p_i preserved
        perm = (np.arange(6) + lead) % 6
        bank_q = push(bank_q, qb[perm])
        bank_p = push(bank_p, pb[perm])
        loss, _ = contrastive_step_loss(q, pp, None, bank_q, bank_p)
        losses.append(float(loss))
    np.testing.assert_allclose(losses[0], losses[1], rtol=1e-6)
    np.testing.assert_allclose(losses[0], losses[2], rtol=1e-6)


def test_grads_flow_to_current_passages_from_bank_query_rows():
    """Paper Eq. 9: bank query rows contribute gradient to *current* passages
    through the softmax columns — the mechanism behind dual-bank stability."""
    d, b = 8, 4
    q = _rand(20, b, d)
    pp = _rand(21, b, d)
    bank_q = push(init_bank(4, d), _rand(22, 4, d))
    bank_p = push(init_bank(4, d), _rand(23, 4, d))

    def loss_only_bank_rows(pp_):
        # mask local rows by feeding orthogonal queries far away? Instead:
        # compute full loss and the local-row-only loss; their difference is
        # the bank-row contribution. Grad of that difference wrt pp must be
        # nonzero.
        full, _ = contrastive_step_loss(q, pp_, None, bank_q, bank_p)
        local_only, _ = contrastive_step_loss(q, pp_, None, None, None)
        return full - local_only

    g = jax.grad(loss_only_bank_rows)(pp)
    assert float(jnp.abs(g).sum()) > 0.0
