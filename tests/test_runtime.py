"""Runtime-layer tests: fault-tolerant trainer (checkpoint/restart, fault
injection, straggler watchdog, preemption), elastic resharding, and the
dynamic-batching retrieval server."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.memory_bank import init_bank, push
from repro.data.loader import LoaderState, ShardedLoader
from repro.distribution.elastic import bank_to_arrays, plan_resize, reshard_bank
from repro.runtime.server import BatchingServer, blocked_topk_scores
from repro.runtime.trainer import StepFailure, Trainer, TrainerConfig


# ---------------------------------------------------------------- trainer
def _counting_step():
    """step_fn over a scalar 'state' counting applied batches."""

    def step(state, batch):
        new = state + batch
        return new, {"loss": float(jnp.asarray(new)) * 0 + 1.0}

    return step


def test_trainer_runs_and_checkpoints(tmp_path):
    step_fn = lambda s, b: (s + b, {"loss": 1.0})
    tr = Trainer(
        TrainerConfig(total_steps=10, checkpoint_dir=str(tmp_path),
                      checkpoint_every=3, log_every=100),
        step_fn,
        next_batch=lambda i: jnp.asarray(1.0),
    )
    state, report = tr.run(jnp.asarray(0.0))
    assert report.steps_run == 10
    assert float(state) == 10.0


def test_trainer_resumes_from_checkpoint(tmp_path):
    step_fn = lambda s, b: (s + b, {"loss": 1.0})
    cfg = TrainerConfig(total_steps=5, checkpoint_dir=str(tmp_path),
                        checkpoint_every=2, log_every=100)
    tr = Trainer(cfg, step_fn, next_batch=lambda i: jnp.asarray(1.0))
    state, _ = tr.run(jnp.asarray(0.0))
    # second trainer continues where the first stopped
    cfg2 = TrainerConfig(total_steps=9, checkpoint_dir=str(tmp_path),
                         checkpoint_every=2, log_every=100)
    tr2 = Trainer(cfg2, step_fn, next_batch=lambda i: jnp.asarray(1.0))
    state2, report2 = tr2.run(jnp.asarray(0.0))
    assert float(state2) == 9.0          # resumed from 5, not restarted at 0
    assert report2.steps_run < 9


def test_trainer_bank_roundtrip_is_bit_identical(tmp_path):
    """ContrastiveState checkpoint round-trip: saving mid-warm-up (banks
    partially filled, ring heads mid-buffer) and restoring must reproduce
    the uninterrupted bank trajectory bit-for-bit — BankState.head/valid/age
    are restored purely by template dtype (int32/bool/int32), so any dtype
    or layout drift in the checkpoint path would desynchronize the rings."""
    import sys

    sys.path.insert(0, "tests")
    from helpers import make_batch, make_mlp_encoder

    from repro.core import ContrastiveConfig, build_step_program, init_state
    from repro.optim import chain, clip_by_global_norm, sgd

    enc = make_mlp_encoder()
    # bank_size 24 and B=8 x K=2: after 3 steps the banks hold 24 of 24 rows
    # with head mid-ring; the interruption at step 2 lands mid-warm-up
    cfg = ContrastiveConfig(method="contaccum", accumulation_steps=2, bank_size=24)
    tx = chain(clip_by_global_norm(2.0), sgd(0.1))
    update = jax.jit(build_step_program(enc, tx, cfg).update)
    batches = {i: make_batch(jax.random.PRNGKey(40 + i), 8) for i in range(6)}

    def trainer(total_steps, ckpt_dir):
        return Trainer(
            TrainerConfig(total_steps=total_steps, checkpoint_dir=ckpt_dir,
                          checkpoint_every=2, log_every=100),
            update,
            next_batch=lambda i: batches[i],
        )

    state0 = init_state(jax.random.PRNGKey(0), enc, tx, cfg)

    # uninterrupted reference: 6 steps straight through, no checkpoint dir
    ref = state0
    for i in range(6):
        ref, _ = update(ref, batches[i])

    # interrupted run: stop after 3 steps (checkpoint at step 2 mid-warm-up),
    # then a fresh trainer restores and continues to 6
    a = str(tmp_path / "roundtrip")
    trainer(3, a).run(state0)
    resumed, report = trainer(6, a).run(state0)
    assert report.steps_run < 6  # proves it resumed, not re-ran

    assert int(resumed.step) == int(ref.step) == 6
    for bank in ("bank_q", "bank_p"):
        got, want = getattr(resumed, bank), getattr(ref, bank)
        np.testing.assert_array_equal(np.asarray(got.buf), np.asarray(want.buf),
                                      err_msg=f"{bank}.buf")
        np.testing.assert_array_equal(np.asarray(got.valid), np.asarray(want.valid))
        np.testing.assert_array_equal(np.asarray(got.age), np.asarray(want.age))
        assert got.valid.dtype == want.valid.dtype == np.bool_
        assert got.head.dtype == want.head.dtype == jnp.int32
        assert int(got.head) == int(want.head), bank
    for a_, b_ in zip(jax.tree_util.tree_leaves(resumed.params),
                      jax.tree_util.tree_leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a_), np.asarray(b_))


def test_trainer_restores_after_injected_fault(tmp_path):
    step_fn = lambda s, b: (s + b, {"loss": 1.0})
    failures = {"at": 6, "done": False}

    def fault_hook(step):
        if step == failures["at"] and not failures["done"]:
            failures["done"] = True
            raise StepFailure("injected node failure")

    tr = Trainer(
        TrainerConfig(total_steps=10, checkpoint_dir=str(tmp_path),
                      checkpoint_every=2, max_restarts=2, log_every=100),
        step_fn,
        next_batch=lambda i: jnp.asarray(1.0),
        fault_hook=fault_hook,
    )
    state, report = tr.run(jnp.asarray(0.0))
    assert report.restarts == 1
    assert float(state) == 10.0          # replayed steps land on the same total


def test_trainer_gives_up_after_max_restarts(tmp_path):
    step_fn = lambda s, b: (s + b, {"loss": 1.0})

    def fault_hook(step):
        if step >= 3:
            raise StepFailure("persistent failure")

    tr = Trainer(
        TrainerConfig(total_steps=10, checkpoint_dir=str(tmp_path),
                      checkpoint_every=1, max_restarts=2, log_every=100),
        step_fn,
        next_batch=lambda i: jnp.asarray(1.0),
        fault_hook=fault_hook,
    )
    with pytest.raises(StepFailure):
        tr.run(jnp.asarray(0.0))


def test_trainer_aborts_restores_on_nan(tmp_path):
    calls = {"n": 0}

    def step_fn(s, b):
        calls["n"] += 1
        if calls["n"] == 4:
            return s, {"loss": float("nan")}
        return s + b, {"loss": 1.0}

    tr = Trainer(
        TrainerConfig(total_steps=6, checkpoint_dir=str(tmp_path),
                      checkpoint_every=1, max_restarts=1, log_every=100),
        step_fn,
        next_batch=lambda i: jnp.asarray(1.0),
    )
    state, report = tr.run(jnp.asarray(0.0))
    assert report.restarts == 1
    assert float(state) == 6.0


def test_straggler_watchdog():
    times = iter([1.0] * 40)  # monotonically consumed fake clock
    clock_state = {"t": 0.0}
    slow_at = 12

    def clock():
        return clock_state["t"]

    def step_fn(s, b):
        # every step advances 10ms, the straggler 200ms
        dt = 0.2 if int(s) == slow_at else 0.01
        clock_state["t"] += dt
        return s + 1, {"loss": 1.0}

    tr = Trainer(
        TrainerConfig(total_steps=20, straggler_factor=3.0,
                      straggler_warmup=3, log_every=100),
        step_fn,
        next_batch=lambda i: 0,
        clock=clock,
    )
    _, report = tr.run(jnp.asarray(0))
    assert report.stragglers == [slow_at]


def test_preemption_stop(tmp_path):
    tr = Trainer(
        TrainerConfig(total_steps=1000, checkpoint_dir=str(tmp_path),
                      log_every=10_000),
        lambda s, b: (s + b, {"loss": 1.0}),
        next_batch=lambda i: jnp.asarray(1.0),
    )

    def stopper(step):
        if step == 7:
            tr.request_stop()

    tr.fault_hook = stopper
    state, report = tr.run(jnp.asarray(0.0))
    assert 7 <= float(state) <= 8        # finished current step, then stopped
    # final checkpoint was written
    from repro.checkpoint.checkpoint import latest_step

    assert latest_step(str(tmp_path)) is not None


# ------------------------------------------------------------------ elastic
def test_elastic_loader_resize_replays_same_global_stream():
    n, gb = 512, 32
    one = ShardedLoader(n, gb, seed=3, host_id=0, n_hosts=1)
    ref = [one.next_indices() for _ in range(10)]

    # 4 hosts, resumed at step 5 with 2 hosts: union must equal the global batch
    hosts4 = [ShardedLoader(n, gb, seed=3, host_id=h, n_hosts=4) for h in range(4)]
    for step in range(5):
        parts = [h.next_indices() for h in hosts4]
        assert np.array_equal(np.sort(np.concatenate(parts)), np.sort(ref[step]))
    state = hosts4[0].state
    hosts2 = [
        ShardedLoader(n, gb, seed=3, host_id=h, n_hosts=2,
                      state=LoaderState(state.epoch, state.step))
        for h in range(2)
    ]
    for step in range(5, 10):
        parts = [h.next_indices() for h in hosts2]
        assert np.array_equal(np.sort(np.concatenate(parts)), np.sort(ref[step]))


def test_plan_resize_picks_divisible_layout():
    p = plan_resize(384, global_batch=128, tp=16)
    assert p.dp * p.tp == 384 and 128 % p.dp == 0
    p2 = plan_resize(96, global_batch=96)
    assert p2.dp * p2.tp == 96 and 96 % p2.dp == 0
    with pytest.raises(ValueError):
        plan_resize(100, global_batch=3, tp=1)


def test_reshard_bank_keeps_newest_in_order():
    bank = init_bank(8, 4)
    for i in range(11):  # wraps: slots hold entries 3..10
        bank = push(bank, jnp.full((1, 4), float(i)), step=i)
    shrunk = reshard_bank(bank_to_arrays(bank), 4)
    kept = sorted(shrunk["buf"][shrunk["valid"]][:, 0].tolist())
    assert kept == [7.0, 8.0, 9.0, 10.0]

    grown = reshard_bank(bank_to_arrays(bank), 16)
    kept = sorted(grown["buf"][grown["valid"]][:, 0].tolist())
    assert kept == [float(i) for i in range(3, 11)]
    assert int(grown["head"]) == 8       # next write appends after the newest


def test_reshard_bank_roundtrip_through_push():
    from repro.distribution.elastic import arrays_to_bank

    bank = init_bank(6, 2)
    for i in range(4):
        bank = push(bank, jnp.full((1, 2), float(i)))
    resized = arrays_to_bank(reshard_bank(bank_to_arrays(bank), 3))
    resized = push(resized, jnp.full((1, 2), 99.0))
    vals = sorted(np.asarray(resized.buf)[np.asarray(resized.valid)][:, 0].tolist())
    assert vals == [2.0, 3.0, 99.0]      # FIFO semantics survive the resize


# ------------------------------------------------------------------- server
def test_blocked_topk_matches_argsort():
    rng = np.random.default_rng(0)
    q = rng.normal(size=(5, 16)).astype(np.float32)
    idx = rng.normal(size=(1000, 16)).astype(np.float32)
    scores, ids = blocked_topk_scores(jnp.asarray(q), jnp.asarray(idx), 10, block=128)
    ref = np.argsort(-(q @ idx.T), axis=1)[:, :10]
    assert np.array_equal(np.asarray(ids), ref)


def test_batching_server_coalesces_and_answers():
    def serve(batch):  # identity "scores": payload sums
        s = batch.sum(axis=1, keepdims=True)
        ids = np.arange(len(batch))[:, None]
        return ids, np.asarray(s)

    srv = BatchingServer(serve, max_batch=8, max_wait_s=0.05).start()
    try:
        futs = [srv.submit(np.full((4,), float(i))) for i in range(20)]
        outs = [f.get(timeout=10) for f in futs]
        for i, (ids, score) in enumerate(outs):
            assert score[0] == pytest.approx(4.0 * i)
        assert max(srv.batch_sizes) > 1   # coalescing actually happened
    finally:
        srv.stop()


def test_batching_server_propagates_errors():
    def serve(batch):
        raise RuntimeError("model exploded")

    srv = BatchingServer(serve, max_batch=4, max_wait_s=0.01).start()
    try:
        fut = srv.submit(np.zeros((2,)))
        res = fut.get(timeout=10)
        assert isinstance(res, RuntimeError)
    finally:
        srv.stop()
