"""PrecisionPolicy (core/precision.py) coverage.

  * fp32 policy is *bit-identical* to the legacy implicit-fp32 behavior.
  * bf16_banks trajectories track the fp32 reference within documented
    tolerance across ALL 12 negative-source x backprop-strategy
    compositions, on both loss backends (dense einsum + fused Pallas kernel
    in interpret mode), with replicated AND sharded bank layouts.
  * Bank rings are allocated in the policy's bank_dtype; the explicit
    ``bank_dtype`` override still wins.
  * Softmax statistics / metrics stay fp32 regardless of input dtype
    (spot-checked here; the hypothesis property suite sweeps it).
  * adamw(keep_master_params=True): fp32 masters in the optimizer state
    track the fp32 reference exactly while the stored params are bf16.

Documented tolerance: bf16 inputs perturb each logit by O(2^-8) relative;
over a 3-step trajectory on the tiny MLP towers the loss stays within 5%
relative and the (fp32-master) params within 5e-2 absolute of the fp32
reference. Statistics keep fp32 *dtype* exactly — only values drift.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PRECISION_PRESETS,
    ContrastiveConfig,
    PrecisionPolicy,
    RetrievalBatch,
    apply_compute_dtype,
    bank_bytes_per_device,
    build_step_program,
    contrastive_loss,
    init_state,
    resolve_precision,
)
from repro.core.loss import DenseLossBackend, FusedLossBackend
from repro.optim import adamw, chain, clip_by_global_norm, sgd
from repro.optim.adamw import apply_updates

from helpers import get_shard_map, make_batch, make_mlp_encoder

SOURCES = ["in_batch", "gathered", "dual_bank", "passage_bank"]
STRATEGIES = ["direct", "scan", "rep_cache"]
BANK_SOURCES = ("dual_bank", "passage_bank")

LOSS_RTOL = 5e-2      # documented bf16-vs-fp32 trajectory tolerance (loss)
PARAM_ATOL = 5e-2     # ... and params (fp32 masters, bf16-perturbed grads)


def _tx():
    return chain(clip_by_global_norm(2.0), sgd(0.1))


def _cfg(neg, bp, *, precision, loss_impl="dense", shard_banks=False):
    needs_mesh = neg == "gathered" or shard_banks
    return ContrastiveConfig(
        negatives=neg,
        backprop=bp,
        accumulation_steps=2 if bp != "direct" else 1,
        bank_size=8 if neg in BANK_SOURCES else 0,
        loss_impl=loss_impl,
        precision=precision,
        dp_axis="dp" if needs_mesh else None,
        shard_banks=shard_banks,
    )


def _run_trajectory(cfg, n_steps=3):
    """3-step trajectory on the MLP towers; returns (losses, fp32 params).
    Mesh-requiring configs run under a 1-device shard_map (same code path,
    CPU-testable)."""
    policy = resolve_precision(cfg.precision)
    enc = make_mlp_encoder()
    if policy.name != "fp32":
        enc = apply_compute_dtype(enc, policy)
    tx = _tx()
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    update = build_step_program(enc, tx, cfg).update
    if cfg.dp_axis is not None:
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.distribution.sharding import contrastive_state_spec

        shard_map, sm_kw = get_shard_map()
        mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
        sspec = contrastive_state_spec(("dp",), cfg.shard_banks)
        bspec = RetrievalBatch(query=P("dp"), passage_pos=P("dp"),
                               passage_hard=P("dp"))
        update = shard_map(update, mesh=mesh, in_specs=(sspec, bspec),
                           out_specs=(sspec, P()), **sm_kw)
    update = jax.jit(update)
    losses = []
    for i in range(n_steps):
        state, m = update(state, make_batch(jax.random.PRNGKey(100 + i), 8,
                                            n_hard=1))
        # metric statistics are fp32 whatever the compute dtype
        assert m.loss.dtype == jnp.float32, cfg
        assert m.accuracy.dtype == jnp.float32, cfg
        losses.append(float(m.loss))
    params = [np.asarray(x, np.float32)
              for x in jax.tree_util.tree_leaves(state.params)]
    return losses, params


_REF_CACHE = {}


def _fp32_reference(neg, bp, loss_impl, shard_banks):
    key = (neg, bp, loss_impl, shard_banks)
    if key not in _REF_CACHE:
        _REF_CACHE[key] = _run_trajectory(
            _cfg(neg, bp, precision="fp32", loss_impl=loss_impl,
                 shard_banks=shard_banks)
        )
    return _REF_CACHE[key]


# ------------------------------------------------------------------ presets
def test_presets_resolve_and_unknown_raises():
    assert set(PRECISION_PRESETS) == {"fp32", "bf16", "bf16_banks"}
    for name, policy in PRECISION_PRESETS.items():
        assert resolve_precision(name) is policy
        assert policy.accum_dtype == jnp.float32
        assert policy.param_dtype == jnp.float32  # masters stay fp32
    assert resolve_precision(None).name == "fp32"
    custom = PrecisionPolicy(name="x", bank_dtype=jnp.bfloat16)
    assert resolve_precision(custom) is custom
    with pytest.raises(ValueError, match="unknown precision"):
        resolve_precision("fp8")
    with pytest.raises(ValueError, match="unknown precision"):
        build_step_program(
            make_mlp_encoder(), _tx(), ContrastiveConfig(precision="nope")
        )


def test_fp32_policy_is_bit_identical_to_legacy_default():
    """precision='fp32' must not change a single bit vs the pre-policy
    behavior (the default-constructed config)."""
    enc = make_mlp_encoder()
    batches = [make_batch(jax.random.PRNGKey(100 + i), 8, n_hard=1)
               for i in range(3)]
    states = []
    for cfg in (
        ContrastiveConfig(method="contaccum", accumulation_steps=2, bank_size=8),
        ContrastiveConfig(method="contaccum", accumulation_steps=2, bank_size=8,
                          precision="fp32"),
    ):
        tx = _tx()
        state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
        update = jax.jit(build_step_program(enc, tx, cfg).update)
        for b in batches:
            state, _ = update(state, b)
        states.append(state)
    for a, b in zip(jax.tree_util.tree_leaves(states[0]),
                    jax.tree_util.tree_leaves(states[1])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- the full trajectory matrix
@pytest.mark.parametrize("loss_impl", ["dense", "fused"])
@pytest.mark.parametrize("bp", STRATEGIES)
@pytest.mark.parametrize("neg", SOURCES)
def test_bf16_trajectory_tracks_fp32_reference(neg, bp, loss_impl):
    """All 12 source x strategy compositions, both backends: the bf16_banks
    trajectory stays within documented tolerance of the fp32 reference."""
    l_ref, p_ref = _fp32_reference(neg, bp, loss_impl, False)
    l_bf, p_bf = _run_trajectory(
        _cfg(neg, bp, precision="bf16_banks", loss_impl=loss_impl)
    )
    np.testing.assert_allclose(l_bf, l_ref, rtol=LOSS_RTOL,
                               err_msg=f"{neg}x{bp}/{loss_impl}: loss")
    for a, b in zip(p_bf, p_ref):
        np.testing.assert_allclose(a, b, atol=PARAM_ATOL,
                                   err_msg=f"{neg}x{bp}/{loss_impl}: params")


@pytest.mark.parametrize("loss_impl", ["dense", "fused"])
@pytest.mark.parametrize("bp", ["scan", "rep_cache"])
@pytest.mark.parametrize("neg", BANK_SOURCES)
def test_bf16_trajectory_with_sharded_banks(neg, bp, loss_impl):
    """Sharded bank layout (shard_map path): bf16_banks still tracks the
    fp32 sharded reference — the bf16 rings shard/push/gather like fp32."""
    l_ref, p_ref = _fp32_reference(neg, bp, loss_impl, True)
    l_bf, p_bf = _run_trajectory(
        _cfg(neg, bp, precision="bf16_banks", loss_impl=loss_impl,
             shard_banks=True)
    )
    np.testing.assert_allclose(l_bf, l_ref, rtol=LOSS_RTOL,
                               err_msg=f"sharded {neg}x{bp}/{loss_impl}: loss")
    for a, b in zip(p_bf, p_ref):
        np.testing.assert_allclose(
            a, b, atol=PARAM_ATOL, err_msg=f"sharded {neg}x{bp}/{loss_impl}"
        )


# ---------------------------------------------------------------- bank dtype
def test_bank_rings_allocated_in_policy_dtype():
    enc = make_mlp_encoder()
    cfg = _cfg("dual_bank", "scan", precision="bf16_banks")
    state = init_state(jax.random.PRNGKey(0), enc, _tx(), cfg)
    assert state.bank_q.buf.dtype == jnp.bfloat16
    assert state.bank_p.buf.dtype == jnp.bfloat16
    # 'bf16' keeps fp32 banks; explicit bank_dtype override beats the policy
    cfg16 = dataclasses.replace(cfg, precision="bf16")
    assert init_state(jax.random.PRNGKey(0), enc, _tx(), cfg16).bank_p.buf.dtype == jnp.float32
    cfg_ovr = dataclasses.replace(cfg, precision="fp32", bank_dtype=jnp.float16)
    assert init_state(jax.random.PRNGKey(0), enc, _tx(), cfg_ovr).bank_p.buf.dtype == jnp.float16


def test_bank_bytes_per_device_math():
    # the README memory table: (N_q + N_p) * d * itemsize / shards
    assert bank_bytes_per_device(2048, 2048, 768, "fp32") == 2 * 2048 * 768 * 4
    assert bank_bytes_per_device(2048, 2048, 768, "bf16_banks") == 2 * 2048 * 768 * 2
    assert (
        bank_bytes_per_device(2048, 2048, 768, "bf16_banks", shards=8)
        == 2 * 2048 * 768 * 2 // 8
    )
    # the acceptance criterion: bf16_banks cuts >= 40% vs fp32 replicated
    red = 1 - bank_bytes_per_device(2048, 2048, 768, "bf16_banks") / \
        bank_bytes_per_device(2048, 2048, 768, "fp32")
    assert red >= 0.40


# ------------------------------------------------------- fp32-stats contract
@pytest.mark.parametrize("backend", ["dense", "fused"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_loss_statistics_are_fp32_for_any_input_dtype(backend, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    q = jax.random.normal(k1, (6, 8)).astype(dtype)
    p = jax.random.normal(k2, (6, 8)).astype(dtype)
    loss_dev, aux = contrastive_loss(q, p, backend=backend)
    assert loss_dev.dtype == jnp.float32
    assert aux.loss.dtype == jnp.float32
    assert aux.accuracy.dtype == jnp.float32
    assert np.isfinite(float(aux.loss))


def test_backend_row_stats_dtype_and_value():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    q32 = jax.random.normal(k1, (5, 8))
    p32 = jax.random.normal(k2, (9, 8))
    labels = jnp.arange(5, dtype=jnp.int32)
    mask = jnp.ones((9,), bool)
    dense = DenseLossBackend()
    ref, _ = dense.row_stats(q32, p32, labels, mask, temperature=1.0)
    for be in (dense, FusedLossBackend(interpret=True)):
        out, correct = be.row_stats(
            q32.astype(jnp.bfloat16), p32.astype(jnp.bfloat16), labels, mask,
            temperature=1.0,
        )
        assert out.dtype == jnp.float32 and correct.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-2, atol=5e-2)


def test_fused_kernel_bf16_grads_match_dense_reference():
    """bf16 q/p through the fused kernel: fp32 stats, bf16 gradients, both
    within bf16 tolerance of the dense fp32-input reference."""
    from repro.core.loss import resolve_loss_backend

    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    q = jax.random.normal(k1, (7, 8))
    p = jax.random.normal(k2, (11, 8))
    labels = jnp.arange(7, dtype=jnp.int32)
    mask = jnp.arange(11) < 9  # two masked columns

    def loss_fn(be, dtype):
        def f(q_, p_):
            out, _ = resolve_loss_backend(be).row_stats(
                q_.astype(dtype), p_.astype(dtype), labels, mask,
                temperature=0.7,
            )
            return out.mean()
        return f

    ref, (gq_ref, gp_ref) = jax.value_and_grad(
        loss_fn("dense", jnp.float32), argnums=(0, 1))(q, p)
    val, (gq, gp) = jax.value_and_grad(
        loss_fn("fused", jnp.bfloat16), argnums=(0, 1))(q, p)
    np.testing.assert_allclose(float(val), float(ref), rtol=2e-2)
    np.testing.assert_allclose(np.asarray(gq, np.float32),
                               np.asarray(gq_ref), atol=2e-2)
    np.testing.assert_allclose(np.asarray(gp, np.float32),
                               np.asarray(gp_ref), atol=2e-2)
    # masked columns get exactly zero gradient, bf16 or not
    np.testing.assert_array_equal(np.asarray(gp, np.float32)[9:], 0.0)


# ----------------------------------------------------------- adamw masters
def test_adamw_master_params_track_fp32_exactly():
    """keep_master_params: bf16 stored params + fp32 masters in the
    optimizer state. With identical (fp32) gradients the master trajectory
    is bit-identical to the all-fp32 run; the bf16 params are the rounded
    masters every step (rounding never compounds)."""
    # start from bf16-representable values so both runs share the same start
    p32 = {"w": jnp.linspace(-1.0, 1.0, 64).astype(jnp.bfloat16).astype(jnp.float32)}
    p16 = jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16), p32)
    g = {"w": jnp.sin(jnp.arange(64, dtype=jnp.float32))}
    tx32, tx16 = adamw(1e-2), adamw(1e-2, keep_master_params=True)
    s32, s16 = tx32.init(p32), tx16.init(p16)
    assert s16.master["w"].dtype == jnp.float32
    a, b = p32, p16
    for _ in range(10):
        u32, s32 = tx32.update(g, s32, a)
        a = apply_updates(a, u32)
        u16, s16 = tx16.update(g, s16, b)
        b = apply_updates(b, u16)
    assert b["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(s16.master["w"]))
    np.testing.assert_allclose(np.asarray(b["w"], np.float32),
                               np.asarray(a["w"]), atol=1e-2)


def test_adamw_without_masters_state_unchanged():
    """Default adamw keeps master=None — no extra optimizer-state memory."""
    p = {"w": jnp.ones((4,), jnp.float32)}
    tx = adamw(1e-3)
    s = tx.init(p)
    assert s.master is None
    _, s = tx.update({"w": jnp.ones((4,))}, s, p)
    assert s.master is None
