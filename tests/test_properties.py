"""Property-based tests (hypothesis) on the system's invariants.

These complement the seeded sweeps in the other test modules with
adversarially-searched counterexamples over the loss, the memory bank, the
data loader and the serving top-k."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.infonce import in_batch_loss, info_nce
from repro.core.loss import contrastive_loss
from repro.core.memory_bank import init_bank, n_valid, push
from repro.data.loader import ShardedLoader
from repro.optim.schedules import linear_warmup_linear_decay
from repro.runtime.server import blocked_topk_scores

_settings = settings(max_examples=25, deadline=None)


def _reps(rng, n, d):
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


@_settings
@given(
    n=st.integers(2, 12),
    d=st.integers(1, 16),
    seed=st.integers(0, 2**16),
    tau=st.floats(0.05, 4.0),
)
def test_infonce_permutation_equivariance(n, d, seed, tau):
    """Permuting (query, positive) pairs together leaves the loss unchanged."""
    rng = np.random.default_rng(seed)
    q, p = _reps(rng, n, d), _reps(rng, n, d)
    perm = rng.permutation(n)
    base = in_batch_loss(q, p, temperature=tau).loss
    permuted = in_batch_loss(q[perm], p[perm], temperature=tau).loss
    np.testing.assert_allclose(base, permuted, rtol=2e-5, atol=2e-6)


@_settings
@given(
    n=st.integers(2, 10),
    n_extra=st.integers(1, 8),
    seed=st.integers(0, 2**16),
)
def test_infonce_extra_negatives_never_decrease_loss(n, n_extra, seed):
    """More negative columns => logsumexp grows => loss is non-decreasing
    (the monotonicity that motivates large batches / memory banks)."""
    rng = np.random.default_rng(seed)
    q, p = _reps(rng, n, 8), _reps(rng, n, 8)
    extra = _reps(rng, n_extra, 8)
    base = info_nce(q, p).loss
    more = info_nce(q, jnp.concatenate([p, extra], axis=0)).loss
    assert float(more) >= float(base) - 1e-6


@_settings
@given(
    n=st.integers(2, 8),
    seed=st.integers(0, 2**16),
)
def test_infonce_masked_rows_do_not_contribute(n, seed):
    rng = np.random.default_rng(seed)
    q, p = _reps(rng, n, 6), _reps(rng, n, 6)
    full = info_nce(q, p).loss
    # append garbage rows, masked out: loss must not change
    garbage = _reps(rng, 3, 6) * 100
    q2 = jnp.concatenate([q, garbage], axis=0)
    labels = jnp.concatenate([jnp.arange(n), jnp.zeros(3, jnp.int32)])
    mask = jnp.concatenate([jnp.ones(n, bool), jnp.zeros(3, bool)])
    masked = info_nce(q2, p, labels=labels, row_mask=mask).loss
    np.testing.assert_allclose(full, masked, rtol=1e-5, atol=1e-6)


@_settings
@given(
    n=st.integers(2, 12),
    d=st.integers(2, 16),
    seed=st.integers(0, 2**16),
    tau=st.floats(0.1, 4.0),
    dtype=st.sampled_from(["float32", "bfloat16", "float16"]),
)
def test_loss_statistics_are_fp32_regardless_of_input_dtype(n, d, seed, tau, dtype):
    """PrecisionPolicy accum contract (core/precision.py): whatever float
    dtype the representations arrive in, every softmax statistic the loss
    reports — loss, accuracy, n_rows, n_negatives — is computed and returned
    in fp32, finite, and within low-precision rounding of the fp32 value."""
    rng = np.random.default_rng(seed)
    q, p = _reps(rng, n, d), _reps(rng, n, d)
    _, ref = contrastive_loss(q, p, temperature=tau)
    loss_dev, aux = contrastive_loss(
        q.astype(dtype), p.astype(dtype), temperature=tau
    )
    for stat in (loss_dev, aux.loss, aux.accuracy, aux.n_rows, aux.n_negatives):
        assert stat.dtype == jnp.float32, dtype
        assert np.isfinite(float(stat))
    # low-precision inputs perturb the value only within rounding tolerance
    np.testing.assert_allclose(float(aux.loss), float(ref.loss),
                               rtol=5e-2, atol=5e-2)


@_settings
@given(
    cap=st.integers(1, 16),
    pushes=st.lists(st.integers(1, 5), min_size=1, max_size=10),
    seed=st.integers(0, 2**16),
)
def test_bank_fifo_keeps_exactly_the_newest(cap, pushes, seed):
    rng = np.random.default_rng(seed)
    bank = init_bank(cap, 2)
    stream = []
    t = 0
    for n in pushes:
        block = np.arange(t, t + n, dtype=np.float32)
        t += n
        stream += block.tolist()
        bank = push(bank, jnp.stack([jnp.asarray(block)] * 2, axis=1))
    expect = sorted(stream[-cap:]) if len(stream) >= cap else sorted(stream)
    got = sorted(np.asarray(bank.buf)[np.asarray(bank.valid)][:, 0].tolist())
    assert got == expect
    assert int(n_valid(bank)) == min(len(stream), cap)


@_settings
@given(
    n=st.integers(64, 512),
    gb_exp=st.integers(2, 5),
    n_hosts=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_loader_host_partition_is_exact(n, gb_exp, n_hosts, seed):
    gb = 2 ** gb_exp * n_hosts
    if n < gb:
        n = gb
    loaders = [
        ShardedLoader(n, gb, seed=seed, host_id=h, n_hosts=n_hosts)
        for h in range(n_hosts)
    ]
    ref = ShardedLoader(n, gb, seed=seed)
    for _ in range(3):
        want = np.sort(ref.next_indices())
        parts = np.concatenate([l.next_indices() for l in loaders])
        assert len(parts) == gb
        assert np.array_equal(np.sort(parts), want)


@_settings
@given(
    peak=st.floats(1e-6, 1.0),
    warm=st.integers(1, 100),
    total=st.integers(102, 1000),
)
def test_schedule_bounds_and_endpoints(peak, warm, total):
    s = linear_warmup_linear_decay(peak, warm, total)
    for step in [0, 1, warm, (warm + total) // 2, total, total + 10]:
        v = float(s(step))
        assert -1e-9 <= v <= peak * (1 + 1e-6)
    assert float(s(warm)) >= 0.9 * peak * (warm / max(warm, 1))
    assert float(s(total + 5)) == 0.0


@_settings
@given(
    n=st.integers(10, 400),
    q=st.integers(1, 6),
    k=st.integers(1, 10),
    block=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_blocked_topk_is_exact(n, q, k, block, seed):
    k = min(k, n)
    rng = np.random.default_rng(seed)
    qs = rng.normal(size=(q, 8)).astype(np.float32)
    idx = rng.normal(size=(n, 8)).astype(np.float32)
    scores, ids = blocked_topk_scores(jnp.asarray(qs), jnp.asarray(idx), k, block=block)
    ref_scores = np.sort(qs @ idx.T, axis=1)[:, ::-1][:, :k]
    np.testing.assert_allclose(np.asarray(scores), ref_scores, rtol=1e-5, atol=1e-5)
