"""Ring-streamed loss (loss_comm='ring') == all-gather trajectories, and the
transient-memory bound the ring path exists to hit.

Three subprocess harnesses on 8 forced host devices (the dry-run isolation
rule keeps the main process at its default 1-device view):

  * **parity**: contaccum/contcache x dense/fused x fp32/bf16 — full
    optimizer trajectories with ring-wrap and partial bank fill, ring vs
    all_gather on the same sharded banks. fp32 agreement is tolerance-level,
    not bit-identical: the ring path logsumexp-merges per-shard chunk stats,
    which reassociates the reduction (measured ~1e-6 over 4 steps); bf16
    rounds the inputs, not the fp32 stats, so it stays within a looser
    tolerance rather than drifting.
  * **ring_rotate VJP**: ppermute's transpose is the inverse rotation —
    a cotangent injected at the receiving device must land back on the
    shard's owner (this is what lets bank dP cotangents "ride home").
  * **transient bound** (pod geometry): compiled temp bytes of one loss
    eval at D in {2, 4, 8} submeshes — all_gather flat in D and at least
    the full N_mem x d block, ring ~1/D (each D-doubling cuts it by >=35%)
    and within 2x of the double-buffered one-shard ideal at D=8.
"""

import os
import subprocess
import sys
import textwrap

import pytest

PARITY_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    sys.path.insert(0, "tests")
    from helpers import get_shard_map, make_mlp_encoder, make_batch
    shard_map, _vma_kw = get_shard_map()
    from repro.core import (
        ContrastiveConfig, RetrievalBatch, init_state, make_update_fn,
    )
    from repro.distribution.sharding import contrastive_state_spec
    from repro.optim import chain, clip_by_global_norm, sgd

    assert jax.device_count() == 8, jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
    DP = ("pod", "data")

    enc = make_mlp_encoder()
    B = 32

    def run(method, k, bank, loss_impl, precision, loss_comm, steps=4):
        cfg = ContrastiveConfig(
            method=method, accumulation_steps=k, bank_size=bank,
            loss_impl=loss_impl, precision=precision,
            dp_axis=DP, shard_banks=True, loss_comm=loss_comm,
        )
        tx = chain(clip_by_global_norm(2.0), sgd(0.05))
        state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
        state_spec = contrastive_state_spec(DP, True)
        batch_spec = RetrievalBatch(
            query=P(DP), passage_pos=P(DP), passage_hard=None
        )
        update = jax.jit(shard_map(
            make_update_fn(enc, tx, cfg),
            mesh=mesh,
            in_specs=(state_spec, batch_spec),
            out_specs=(state_spec, P()),
            **_vma_kw,
        ))
        losses, accs, negs = [], [], []
        for i in range(steps):
            batch = make_batch(jax.random.PRNGKey(100 + i), B, n_hard=1)
            state, m = update(state, batch)
            losses.append(float(m.loss))
            accs.append(float(m.accuracy))
            negs.append(float(m.n_negatives))
        return state, losses, accs, negs

    # bank=16 (cap/D=2) wraps mid-trajectory; bank=24 (cap/D=3) wraps
    # UNEVENLY (24 rows vs 16-row pushes), so every step sees a partially
    # refilled ring; contcache's 128 stays eviction-safe. The first loss
    # eval of every run streams a partially VALID bank (cold start).
    CASES = [
        ("contaccum", 2, 16), ("contaccum", 2, 24), ("contcache", 2, 128),
    ]
    for method, k, bank in CASES:
        for loss_impl in ("dense", "fused"):
            for precision in ("fp32", "bf16"):
                tag = f"{method}/bank{bank}/{loss_impl}/{precision}"
                sg, lg, ag, ng = run(method, k, bank, loss_impl, precision,
                                     "all_gather")
                sr, lr, ar, nr = run(method, k, bank, loss_impl, precision,
                                     "ring")
                lt = dict(rtol=2e-5, atol=2e-6) if precision == "fp32" \\
                    else dict(rtol=2e-3, atol=2e-3)
                pt = dict(rtol=1e-4, atol=1e-6) if precision == "fp32" \\
                    else dict(rtol=1e-2, atol=1e-4)
                np.testing.assert_allclose(lg, lr, err_msg=tag, **lt)
                # n_negatives counts the same global columns in both modes
                np.testing.assert_array_equal(ng, nr, err_msg=tag)
                np.testing.assert_allclose(ag, ar, atol=1e-6, err_msg=tag)
                for a, b in zip(
                    jax.tree_util.tree_leaves(sg.params),
                    jax.tree_util.tree_leaves(sr.params),
                ):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32), np.asarray(b, np.float32),
                        err_msg=tag, **pt,
                    )
                # identical push schedule -> identical ring state
                for bn in ("bank_q", "bank_p"):
                    bg, br = getattr(sg, bn), getattr(sr, bn)
                    assert int(bg.head) == int(br.head), tag
                    np.testing.assert_array_equal(
                        np.asarray(bg.valid), np.asarray(br.valid), err_msg=tag
                    )
                print(f"OK {tag}: ring == all_gather, losses {lr}")
    print("ALL-OK")
    """
)


ROTATE_VJP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    sys.path.insert(0, "tests")
    from helpers import get_shard_map
    shard_map, _vma_kw = get_shard_map()
    from repro.core.dist import DistCtx

    D = 8
    assert jax.device_count() == D, jax.device_count()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("pod", "data"))
    ctx = DistCtx(("pod", "data"))

    x = jnp.arange(D, dtype=jnp.float32).reshape(D, 1)   # shard i holds [i]
    c = (jnp.arange(D, dtype=jnp.float32) + 1.0).reshape(D, 1)

    def fwd(x, c):
        y = ctx.ring_rotate(x, 1)          # device j receives x_{(j-1)%D}
        return ctx.psum(jnp.sum(y * c)), y

    f = jax.jit(shard_map(
        fwd, mesh=mesh, in_specs=(P(("pod", "data")), P(("pod", "data"))),
        out_specs=(P(), P(("pod", "data"))), **_vma_kw,
    ))
    loss, y = f(x, c)
    # value: rotation by one in flattened (pod, data) ring order
    np.testing.assert_array_equal(
        np.asarray(y).ravel(), np.roll(np.arange(D, dtype=np.float32), 1)
    )
    # loss = sum_j c_j * x_{(j-1)%D} = sum_i c_{(i+1)%D} * x_i
    expect = float(np.sum(np.roll(np.arange(D) + 1.0, -1) * np.arange(D)))
    assert abs(float(loss) - expect) < 1e-5, (float(loss), expect)

    # VJP: differentiate the device-LOCAL contribution sum_j c_j * y_j (no
    # psum: its check_rep=False transpose re-reduces and scales by D). The
    # cotangent c_j is created on the RECEIVING device j, and ppermute's
    # transpose (the inverse rotation) must deliver it back to the shard's
    # owner: d/dx_i = c_{(i+1)%D}.
    g = jax.jit(shard_map(
        jax.grad(lambda x, c: jnp.sum(ctx.ring_rotate(x, 1) * c)), mesh=mesh,
        in_specs=(P(("pod", "data")), P(("pod", "data"))),
        out_specs=P(("pod", "data")), **_vma_kw,
    ))(x, c)
    np.testing.assert_array_equal(
        np.asarray(g).ravel(), np.roll(np.arange(D) + 1.0, -1)
    )

    # D rotations return every shard to its owner (the bwd ring invariant)
    def full_circle(x):
        for _ in range(D):
            x = ctx.ring_rotate(x, 1)
        return x

    rt = jax.jit(shard_map(
        full_circle, mesh=mesh, in_specs=(P(("pod", "data")),),
        out_specs=P(("pod", "data")), **_vma_kw,
    ))(x)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(x))
    print("ALL-OK")
    """
)


# Pod-geometry dry-run for the transient bound: one forced-8-device process,
# submeshes of 2 / 4 / 8 devices (8 = (2,4) pod x data, exercising the
# flattened two-axis ring). Compile-only: bytes come from XLA's memory
# analysis, nothing executes.
TRANSIENT_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as P

    sys.path.insert(0, "tests")
    from helpers import get_shard_map
    shard_map, _vma_kw = get_shard_map()
    from repro.core.dist import DistCtx
    from repro.core.loss import FusedLossBackend, contrastive_loss, \\
        sharded_bank_extra_columns
    from repro.core.memory_bank import BankState

    N_MEM, REP_D, B_LOCAL = 2048, 64, 8
    assert jax.device_count() == 8, jax.device_count()

    def mesh_for(d):
        devs = np.array(jax.devices()[:d])
        if d == 8:
            return Mesh(devs.reshape(2, 4), ("pod", "data")), ("pod", "data")
        return Mesh(devs, ("data",)), ("data",)

    backend = FusedLossBackend(interpret=True)

    def temp_bytes(d, comm, grad):
        mesh, dp = mesh_for(d)
        ctx = DistCtx(dp)
        B = B_LOCAL * d
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.standard_normal((B, REP_D)), jnp.float32)
        pp = jnp.asarray(rng.standard_normal((B, REP_D)), jnp.float32)
        pbuf = jnp.asarray(rng.standard_normal((N_MEM, REP_D)), jnp.float32)
        valid = jnp.ones((N_MEM,), bool)

        def eval_loss(q, pp, pbuf, valid):
            extra = None
            if comm is not None:
                bank = BankState(
                    buf=pbuf, valid=valid,
                    head=jnp.zeros((), jnp.int32),
                    age=jnp.zeros((pbuf.shape[0],), jnp.int32),
                )
                extra = sharded_bank_extra_columns(bank, ctx, comm)

            def f(q):
                loss, _ = contrastive_loss(
                    q, pp, extra_cols=extra, temperature=0.5,
                    ctx=ctx, backend=backend,
                )
                return loss

            if grad:
                return jax.value_and_grad(f)(q)
            return f(q), q

        row = P(dp)
        fn = jax.jit(shard_map(
            eval_loss, mesh=mesh, in_specs=(row,) * 4,
            out_specs=(P(), row), **_vma_kw,
        ))
        mem = fn.lower(q, pp, pbuf, valid).compile().memory_analysis()
        return float(getattr(mem, "temp_size_in_bytes", 0))

    KIB = 1024.0
    bank_bytes = N_MEM * REP_D * 4
    for grad in (False, True):
        stage = "grad" if grad else "fwd"
        base = {d: temp_bytes(d, None, grad) for d in (2, 4, 8)}
        ag = {d: temp_bytes(d, "all_gather", grad) for d in (2, 4, 8)}
        ring = {d: temp_bytes(d, "ring", grad) for d in (2, 4, 8)}
        print(f"{stage}: base={base} all_gather={ag} ring={ring}", flush=True)

        # all_gather: flat in D, and holds the full gathered bank block
        assert max(ag.values()) / min(ag.values()) < 1.05, (stage, ag)
        assert min(ag.values()) >= bank_bytes, (stage, ag, bank_bytes)
        # ring: each D-doubling sheds at least 35% of the transient
        assert ring[4] <= 0.65 * ring[2], (stage, ring)
        assert ring[8] <= 0.65 * ring[4], (stage, ring)
        # D=8 bank-attributable transient within 2x of the double-buffered
        # one-shard ideal: fwd carries one shard-sized buffer (the rotating
        # shard + its ppermute ping-pong), the bwd ring carries two (the
        # shard and the dP cotangent riding home with it)
        ideal2 = (2 if grad else 1) * 2 * (bank_bytes // 8)
        assert ring[8] - base[8] <= 2 * ideal2, (stage, ring, base, ideal2)
        if grad:
            # the headline: backward ring stays ~1/D too (custom VJP
            # re-streams shards instead of saving all D as residuals)
            assert ring[8] <= 0.25 * ag[8], (stage, ring, ag)
    print("ALL-OK")
    """
)


@pytest.mark.slow
def test_ring_matches_all_gather_trajectories():
    """loss_comm='ring' reproduces the all_gather trajectory for
    contaccum/contcache x dense/fused x fp32/bf16, through bank wrap and
    partial fill."""
    _run_subprocess(PARITY_SCRIPT)


@pytest.mark.slow
def test_ring_rotate_value_and_vjp_ownership():
    _run_subprocess(ROTATE_VJP_SCRIPT)


@pytest.mark.slow
def test_ring_transient_memory_scales_inverse_d():
    """Compiled temp bytes: all_gather flat and >= full bank block; ring
    ~1/D with the D=8 bank share within 2x of one double-buffered shard."""
    _run_subprocess(TRANSIENT_SCRIPT, timeout=900)


def _run_subprocess(script, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src:tests"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=timeout,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL-OK" in proc.stdout
