"""Kernel-parity suite: the fused (Pallas) loss backend vs the dense einsum.

Mirrors the seed-parity pattern of tests/seed_methods.py at the backend
level: for every NegativeSource x BackpropStrategy composition in the
registry, a multi-step trajectory with ``loss_impl='fused'`` must track the
``loss_impl='dense'`` trajectory to fp32 tolerance — same params, same
banks, same metrics. That covers both VJPs (dQ through the query tower, dP
through the passage tower), masked warm-up bank slots, and weighted
ExtraRows. Everything runs in interpret mode on CPU.
"""

import importlib.util
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ContrastiveConfig,
    DenseLossBackend,
    ExtraColumns,
    ExtraRows,
    FusedLossBackend,
    RetrievalBatch,
    SOURCES,
    STRATEGIES,
    build_step_program,
    contrastive_loss,
    init_state,
    resolve_loss_backend,
)
from repro.kernels.fused_infonce.ops import fused_infonce_stats
from repro.kernels.fused_infonce.ref import infonce_stats_ref
from repro.optim import chain, clip_by_global_norm, sgd

from helpers import get_shard_map, make_batch, make_mlp_encoder

ALL_COMPOSITIONS = [
    (neg, bp) for neg in sorted(SOURCES) for bp in sorted(STRATEGIES)
]

FUSED = FusedLossBackend(interpret=True)
DENSE = DenseLossBackend()


def _tx():
    return chain(clip_by_global_norm(2.0), sgd(0.1))


def _cfg(neg, bp, loss_impl):
    return ContrastiveConfig(
        negatives=neg,
        backprop=bp,
        accumulation_steps=1 if bp == "direct" else 2,
        # bank > one update's pushes: the warm-up phase (masked invalid
        # slots) stays in play across the whole trajectory
        bank_size=12 if neg in ("dual_bank", "passage_bank") else 0,
        dp_axis="dp" if neg == "gathered" else None,
        loss_impl=loss_impl,
    )


def _run_trajectory(neg, bp, loss_impl, batches):
    enc = make_mlp_encoder()
    cfg = _cfg(neg, bp, loss_impl)
    tx = _tx()
    program = build_step_program(enc, tx, cfg)
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    if neg == "gathered":
        from jax.sharding import Mesh, PartitionSpec as P

        shard_map, sm_kw = get_shard_map()
        mesh = Mesh(np.array(jax.devices()[:1]), ("dp",))
        spec = RetrievalBatch(query=P("dp"), passage_pos=P("dp"),
                              passage_hard=P("dp"))
        update = jax.jit(shard_map(
            program.update, mesh=mesh, in_specs=(P(), spec),
            out_specs=(P(), P()), **sm_kw,
        ))
    else:
        update = jax.jit(program.update)
    metrics = []
    for b in batches:
        state, m = update(state, b)
        metrics.append(m)
    return state, metrics


def _assert_tree_close(a, b, msg, rtol=3e-5, atol=1e-6):
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(
            np.asarray(la), np.asarray(lb), rtol=rtol, atol=atol, err_msg=msg
        )


# ------------------------------------------------------- registry-wide parity
@pytest.mark.parametrize("neg,bp", ALL_COMPOSITIONS)
def test_fused_backend_matches_dense_across_registry(neg, bp):
    """3-step trajectories per composition: params, banks and metrics under
    loss_impl='fused' must track 'dense' (both encoder VJPs, warm-up masks,
    weighted rows all exercised through the real update programs)."""
    batches = [make_batch(jax.random.PRNGKey(100 + i), 8, n_hard=1)
               for i in range(3)]
    s_dense, m_dense = _run_trajectory(neg, bp, "dense", batches)
    s_fused, m_fused = _run_trajectory(neg, bp, "fused", batches)
    _assert_tree_close(s_dense.params, s_fused.params, f"{neg}x{bp}: params")
    for bank in ("bank_q", "bank_p"):
        _assert_tree_close(
            getattr(s_dense, bank), getattr(s_fused, bank), f"{neg}x{bp}: {bank}"
        )
    for md, mf in zip(m_dense, m_fused):
        for field in ("loss", "accuracy", "grad_norm", "grad_norm_ratio",
                      "n_negatives", "bank_fill_q", "bank_fill_p"):
            np.testing.assert_allclose(
                float(getattr(md, field)), float(getattr(mf, field)),
                rtol=1e-4, atol=1e-6, err_msg=f"{neg}x{bp}: metric {field}",
            )


# ------------------------------------------------- loss-level fwd/VJP parity
def test_loss_level_parity_masked_columns_weighted_rows():
    """contrastive_loss forward value, accuracy, and the VJPs w.r.t. every
    input block agree between backends — with invalid extra columns (warm-up
    masking) and fractionally weighted ExtraRows (the replicated-bank-row
    1/D shares)."""
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    b, d, c, r = 8, 16, 10, 6
    q = jax.random.normal(ks[0], (b, d))
    pp = jax.random.normal(ks[1], (b, d))
    ph = jax.random.normal(ks[2], (2 * b, d))
    cols = ExtraColumns(
        reps=jax.random.normal(ks[3], (c, d)),
        valid=jnp.arange(c) < 7,                  # 3 masked warm-up slots
    )
    rows = ExtraRows(
        reps=jax.random.normal(ks[4], (r, d)),
        labels=jnp.arange(r, dtype=jnp.int32),    # into the extra-col block
        weight=jax.random.uniform(ks[5], (r,)),   # fractional weights
    )

    def make_loss(backend):
        def loss(q_, pp_, ph_, cr_, rr_):
            l, aux = contrastive_loss(
                q_, pp_, ph_,
                extra_cols=ExtraColumns(reps=cr_, valid=cols.valid),
                extra_rows=ExtraRows(reps=rr_, labels=rows.labels,
                                     weight=rows.weight),
                temperature=0.7,
                backend=backend,
            )
            return l, aux
        return loss

    args = (q, pp, ph, cols.reps, rows.reps)
    (ld, auxd), gd = jax.value_and_grad(make_loss(DENSE), argnums=(0, 1, 2, 3, 4),
                                        has_aux=True)(*args)
    (lf, auxf), gf = jax.value_and_grad(make_loss(FUSED), argnums=(0, 1, 2, 3, 4),
                                        has_aux=True)(*args)
    np.testing.assert_allclose(float(ld), float(lf), rtol=1e-5)
    np.testing.assert_allclose(float(auxd.accuracy), float(auxf.accuracy), rtol=1e-6)
    for name, a, b_ in zip(("dq", "dpp", "dph", "dcols", "drows"), gd, gf):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-6,
            err_msg=f"VJP mismatch: {name}",
        )
    # masked extra columns must receive exactly zero gradient on both paths
    np.testing.assert_array_equal(np.asarray(gd[3][7:]), 0.0)
    np.testing.assert_array_equal(np.asarray(gf[3][7:]), 0.0)


# ------------------------------------------------------ ragged-shape padding
@pytest.mark.parametrize(
    "m,n,d,bm,bn",
    [
        (96, 200, 64, 128, 128),   # the ISSUE's regression shape
        (1, 333, 16, 128, 128),    # single row, ragged columns
        (130, 70, 8, 64, 32),      # both dims ragged vs the blocks
        (257, 129, 32, 128, 128),  # one past the block boundary
    ],
)
def test_odd_shapes_are_padded_internally(m, n, d, bm, bn):
    """No more `m % block_m == 0` assert: padded columns are masked to
    NEG_INF, padded rows are dropped, stats and both VJPs stay exact."""
    ks = jax.random.split(jax.random.PRNGKey(m * 7 + n), 4)
    q = jax.random.normal(ks[0], (m, d))
    p = jax.random.normal(ks[1], (n, d))
    labels = jax.random.randint(ks[2], (m,), 0, n)
    valid = jax.random.bernoulli(ks[3], 0.8, (n,)).at[labels].set(True)
    lse, pos, amax = fused_infonce_stats(q, p, labels, valid, 1.3, bm, bn, True)
    lse_r, pos_r, amax_r = infonce_stats_ref(q, p, labels, valid, inv_tau=1.3)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(pos), np.asarray(pos_r), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(amax), np.asarray(amax_r), rtol=1e-5)

    w = jax.random.uniform(ks[3], (m,))

    def k_loss(q_, p_):
        l, po, _ = fused_infonce_stats(q_, p_, labels, valid, 1.3, bm, bn, True)
        return jnp.sum((l - po) * w)

    def r_loss(q_, p_):
        l, po, _ = infonce_stats_ref(q_, p_, labels, valid, inv_tau=1.3)
        return jnp.sum((l - po) * w)

    gk = jax.grad(k_loss, argnums=(0, 1))(q, p)
    gr = jax.grad(r_loss, argnums=(0, 1))(q, p)
    for name, a, b in zip(("dq", "dp"), gk, gr):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=5e-6,
            err_msg=f"odd-shape VJP mismatch: {name}",
        )


@pytest.mark.slow
def test_large_bank_sweep_parity():
    """Large-shape sweep (bank-scale column counts) — slow, interpret mode."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    m, n, d = 256, 8192 + 57, 64
    q = jax.random.normal(ks[0], (m, d))
    p = jax.random.normal(ks[1], (n, d))
    labels = jax.random.randint(ks[2], (m,), 0, n)
    valid = jnp.arange(n) < (n - 100)
    lse, pos, amax = fused_infonce_stats(q, p, labels, valid, 1.0, 128, 512, True)
    lse_r, pos_r, amax_r = infonce_stats_ref(q, p, labels, valid)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(amax), np.asarray(amax_r), rtol=1e-5)


def test_merge_row_stats_composes_chunked_softmax_exactly():
    """(lse, pos, amax) are sufficient statistics: computing them per column
    chunk and logsumexp-merging must reproduce the whole-matrix stats — both
    values and gradients (the chain rule through the merge rescales each
    chunk's cotangent by exp(lse_k - lse), making chunk-local softmax
    coefficients global). This identity is what lets the ring loss stream
    one bank shard at a time."""
    from repro.kernels.fused_infonce.ops import merge_row_stats

    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    m, n, d, n_chunks = 16, 48, 8, 4
    q = jax.random.normal(ks[0], (m, d))
    p = jax.random.normal(ks[1], (n, d))
    labels = jax.random.randint(ks[2], (m,), 0, n)
    valid = jnp.arange(n) % 7 != 0  # masked columns inside chunks

    def whole(q, p):
        return infonce_stats_ref(q, p, labels, valid)

    def chunked(q, p):
        c = n // n_chunks
        parts = []
        for k in range(n_chunks):
            lse, pos, amax = infonce_stats_ref(
                q, p[k * c:(k + 1) * c],
                jnp.clip(labels - k * c, 0, c - 1),
                valid[k * c:(k + 1) * c],
            )
            owns = (labels >= k * c) & (labels < (k + 1) * c)
            pos = jnp.where(owns, pos, 0.0)
            parts.append((lse, pos, owns, amax))
        lse, pos, owns, amax = (jnp.stack(x) for x in zip(*parts))
        return merge_row_stats(lse, pos, owns, amax)

    for a, b in zip(whole(q, p), chunked(q, p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    # gradients of the actual training objective mean(lse - pos)
    def loss(stats_fn):
        def f(q, p):
            lse, pos, _ = stats_fn(q, p)
            return jnp.mean(lse - pos)
        return f

    gq_w, gp_w = jax.grad(loss(whole), argnums=(0, 1))(q, p)
    gq_c, gp_c = jax.grad(loss(chunked), argnums=(0, 1))(q, p)
    np.testing.assert_allclose(np.asarray(gq_w), np.asarray(gq_c), rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(gp_w), np.asarray(gp_c), rtol=1e-5,
                               atol=1e-7)


# ----------------------------------------------------------------- plumbing
def test_default_backend_is_dense():
    assert ContrastiveConfig().loss_impl == "dense"
    assert resolve_loss_backend(None).name == "dense"
    assert resolve_loss_backend("fused").name == "fused"
    # instances pass through
    be = FusedLossBackend(block_n=64, interpret=True)
    assert resolve_loss_backend(be) is be


def test_unknown_loss_impl_raises_at_build():
    enc = make_mlp_encoder()
    with pytest.raises(ValueError, match="unknown loss_impl"):
        build_step_program(enc, _tx(), ContrastiveConfig(loss_impl="nope"))


def test_fused_cell_is_registered_and_traces():
    """The dpr-bert-base fused cell builds and abstract-evals (the Pallas
    call shape-checks without a TPU)."""
    from jax.sharding import Mesh

    from repro.launch.steps import build_cell

    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    prog = build_cell("dpr-bert-base", "paper_batch_fused", mesh)
    assert prog.static_info["loss_impl"] == "fused"
    assert prog.static_info["method"] == "contaccum"
    out = jax.eval_shape(prog.fn, *prog.args)
    assert out is not None


def test_example_driver_runs_fused():
    """examples/train_retriever.py drives loss_impl='fused' end to end."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "examples", "train_retriever.py")
    spec = importlib.util.spec_from_file_location("example_train_retriever", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    mod.main([
        "--method", "contaccum",
        "--loss-impl", "fused",
        "--steps", "2",
        "--warmup-steps", "1",
        "--total-batch", "8",
        "--local-batch", "4",
        "--bank", "12",
        "--corpus", "64",
    ])
