"""Executable versions of the paper's empirical/theoretical claims.

  * Sec. 3.3 / Fig. 5: passage-only memory bank (pre-batch negatives) causes
    gradient-norm imbalance (||∇Λ|| / ||∇Θ|| drifts well above 1); the dual
    bank keeps the ratio near 1 (like DPR).
  * Sec. 3.2: ContAccum can exceed the total batch's negative count.
  * Appendix C: past representations keep non-negligible similarity mass.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ContrastiveConfig, init_state, make_update_fn
from repro.optim import adamw, chain, clip_by_global_norm

from helpers import make_batch, make_mlp_encoder


def _train_ratio_trace(cfg, n_steps=60, lr=5e-3, seed=0):
    enc = make_mlp_encoder()
    tx = chain(clip_by_global_norm(cfg.grad_clip_norm), adamw(lr))
    state = init_state(jax.random.PRNGKey(seed), enc, tx, cfg)
    update = jax.jit(make_update_fn(enc, tx, cfg))
    ratios = []
    for i in range(n_steps):
        batch = make_batch(jax.random.PRNGKey(1000 + i), 16)
        state, metrics = update(state, batch)
        ratios.append(float(metrics.grad_norm_ratio))
    return np.array(ratios)


def test_gradient_norm_imbalance_passage_only_bank():
    """Fig. 5 / Sec. 3.3: a passage-only bank (pre-batch negatives) makes the
    two encoders' gradient norms diverge; the dual bank keeps them balanced.

    We assert the *magnitude* of the imbalance |log(||∇Λ||/||∇Θ||)|. At toy
    scale (MLP towers, synthetic vectors) the imbalance reliably appears but
    its *sign* is architecture-dependent — the paper's BERT setup drifts to
    ratio ≫ 1, the toy drifts < 1. The paper's own analysis (Eq. 8/9) is
    symmetric in which encoder wins; the instability claim is about the
    divergence itself, which this test pins down.
    """
    base = dict(method="contaccum", accumulation_steps=2, bank_size=64)
    dual = _train_ratio_trace(ContrastiveConfig(**base), n_steps=120, lr=1e-2)
    p_only = _train_ratio_trace(
        ContrastiveConfig(**base, use_query_bank=False), n_steps=120, lr=1e-2
    )

    imb_dual = np.abs(np.log(dual[-20:])).mean()
    imb_ponly = np.abs(np.log(p_only[-20:])).mean()
    # dual bank: balanced (paper: close to 1).
    assert imb_dual < 0.8, f"dual-bank ratio drifted: {np.exp(imb_dual)}"
    # passage-only: clearly more imbalanced than dual.
    assert imb_ponly > imb_dual + 0.4, (imb_ponly, imb_dual)
    assert imb_ponly > 0.9, imb_ponly


def test_dpr_baseline_is_balanced():
    cfg = ContrastiveConfig(method="dpr")
    ratios = _train_ratio_trace(cfg, n_steps=30)
    assert 0.5 < ratios[-10:].mean() < 2.0


def test_similarity_mass_of_past_representations():
    """Appendix C: passages cached a few steps ago still carry similarity mass
    comparable to current in-batch passages (they remain useful negatives)."""
    enc = make_mlp_encoder()
    cfg = ContrastiveConfig(method="contaccum", accumulation_steps=1, bank_size=32)
    tx = chain(clip_by_global_norm(2.0), adamw(1e-3))
    state = init_state(jax.random.PRNGKey(0), enc, tx, cfg)
    update = jax.jit(make_update_fn(enc, tx, cfg))
    for i in range(8):
        state, _ = update(state, make_batch(jax.random.PRNGKey(i), 8))

    batch = make_batch(jax.random.PRNGKey(99), 8)
    q = enc.encode_query(state.params, batch.query)
    p_now = enc.encode_passage(state.params, batch.passage_pos)
    # softmax mass of current vs banked passages for current queries
    cols = jnp.concatenate([p_now, state.bank_p.buf], axis=0)
    sims = jax.nn.softmax(q @ cols.T, axis=-1)
    mass_now = float(sims[:, :8].sum(1).mean()) / 8
    mass_bank = float(sims[:, 8:].sum(1).mean()) / 32
    # per-passage mass of banked reps within 10x of current ones
    assert mass_bank > 0.1 * mass_now, (mass_bank, mass_now)


def test_contaccum_beats_gradaccum_on_synthetic_retrieval():
    """Directional version of Table 1 at toy scale: with the same local batch,
    ContAccum's extra negatives should not hurt final training loss (seeded)."""
    enc = make_mlp_encoder()

    def final_acc(cfg, seed=0, steps=80):
        tx = chain(clip_by_global_norm(2.0), adamw(5e-3))
        state = init_state(jax.random.PRNGKey(seed), enc, tx, cfg)
        update = jax.jit(make_update_fn(enc, tx, cfg))
        accs = []
        for i in range(steps):
            state, m = update(state, make_batch(jax.random.PRNGKey(i % 17), 16))
            accs.append(float(m.accuracy))
        return np.mean(accs[-10:])

    acc_ga = final_acc(ContrastiveConfig(method="grad_accum", accumulation_steps=4))
    acc_ca = final_acc(
        ContrastiveConfig(method="contaccum", accumulation_steps=4, bank_size=64)
    )
    # ContAccum sees 4+64-1 negatives vs GradAccum's 3; the task is harder but
    # the learned embeddings should at minimum remain competitive.
    assert acc_ca > 0.5 * acc_ga, (acc_ca, acc_ga)
