"""Dry-run machinery smoke: build_cell -> lower -> compile -> analyze on a
small forced-device mesh, one representative cell per family. Runs in a
subprocess so the main pytest process keeps its 1-device view."""

import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    from jax.sharding import Mesh

    assert jax.device_count() == 8
    # shrink the production mesh to (4 data, 2 model) for the smoke
    import repro.launch.mesh as mesh_mod
    small = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))

    from repro.launch.steps import build_cell, list_cells
    from repro.launch import hlo_analysis as H

    # one representative (cheap) cell per family. The shard_map xdev cells
    # are traced (not compiled) in tests/test_step_program.py — compiling
    # bert-base at B=2048 under shard_map costs ~9 min on CPU, which would
    # blow this subprocess's timeout; their collective mechanics are
    # compile-tested at MLP scale in tests/test_distributed.py.
    cells = [
        ("schnet", "molecule"),
        ("deepfm", "serve_p99"),
        ("dpr-bert-base", "paper_batch"),
        ("dpr-bert-base", "contcache_batch"),
    ]
    for arch, shape in cells:
        prog = build_cell(arch, shape, small)
        jitted = jax.jit(prog.fn, donate_argnums=prog.donate_argnums)
        compiled = jitted.lower(*prog.args).compile()
        raw_flops, _ = H.cost_numbers(compiled)
        stats = H.analyze_hlo(compiled.as_text(), 8)
        roof = H.roofline(stats, raw_flops=raw_flops)
        assert roof.t_compute >= 0 and roof.t_memory > 0, (arch, shape)
        mem = H.memory_numbers(compiled)
        assert mem.get("total_bytes", 1) > 0
        print(f"{arch}/{shape}: OK dominant={roof.dominant}")

    # the full cell list covers all 10 assigned archs x their shapes
    all_cells = list_cells()
    archs = {a for a, _ in all_cells}
    assert len(archs) == 11, sorted(archs)   # 10 assigned + dpr-bert-base
    # 50 training + serve_topk/eval_topk + paper_batch_mined/contaccum_mined
    assert len(all_cells) == 54, len(all_cells)
    print("CELL_LIST_OK")
    """
)


@pytest.mark.slow
def test_dryrun_smoke_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
    )
    assert res.returncode == 0, res.stderr[-3000:]
    assert "CELL_LIST_OK" in res.stdout
