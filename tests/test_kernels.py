"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode on CPU).

Shapes/dtypes swept per the deliverable spec; gradients checked through the
custom VJPs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.fused_infonce.ops import fused_infonce_loss, fused_infonce_rows
from repro.kernels.fused_infonce.ref import (
    infonce_grads_ref,
    infonce_loss_ref,
    infonce_rows_ref,
)


# ---------------------------------------------------------------- fused infonce
@pytest.mark.parametrize(
    "m,n,d,bm,bn",
    [
        (128, 128, 32, 128, 128),
        (256, 384, 64, 128, 128),
        (64, 192, 16, 32, 64),     # sub-MXU blocks still correct
        (512, 512, 128, 128, 256),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_infonce_fwd_sweep(m, n, d, bm, bn, dtype):
    ks = jax.random.split(jax.random.PRNGKey(m + n), 3)
    q = jax.random.normal(ks[0], (m, d), dtype)
    p = jax.random.normal(ks[1], (n, d), dtype)
    labels = jax.random.randint(ks[2], (m,), 0, n)
    lse, pos = fused_infonce_rows(q, p, labels, 1.3, bm, bn, True)
    lse_r, pos_r = infonce_rows_ref(q, p, labels, inv_tau=1.3)
    rtol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_r), rtol=rtol)
    np.testing.assert_allclose(np.asarray(pos), np.asarray(pos_r), rtol=rtol, atol=1e-6)


@pytest.mark.parametrize("m,n,d", [(128, 256, 32), (256, 256, 64)])
def test_fused_infonce_grads_match_oracle(m, n, d):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (m, d))
    p = jax.random.normal(ks[1], (n, d))
    labels = jax.random.randint(ks[2], (m,), 0, n)
    gq, gp = jax.grad(
        lambda q_, p_: fused_infonce_loss(q_, p_, labels, temperature=0.7),
        argnums=(0, 1),
    )(q, p)
    gq_r, gp_r = infonce_grads_ref(q, p, labels, inv_tau=1.0 / 0.7)
    np.testing.assert_allclose(np.asarray(gq), np.asarray(gq_r), rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gp_r), rtol=1e-4, atol=1e-7)


def test_fused_infonce_loss_value_jit():
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    q = jax.random.normal(ks[0], (128, 32))
    p = jax.random.normal(ks[1], (128, 32))
    loss = jax.jit(lambda a, b: fused_infonce_loss(a, b))(q, p)
    loss_r = infonce_loss_ref(q, p, jnp.arange(128, dtype=jnp.int32))
    np.testing.assert_allclose(float(loss), float(loss_r), rtol=1e-6)


def test_fused_infonce_weighted_row_cotangents():
    """Generalized VJP: arbitrary per-row weights (masked bank rows etc.)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    m, n, d = 128, 128, 32
    q = jax.random.normal(ks[0], (m, d))
    p = jax.random.normal(ks[1], (n, d))
    labels = jnp.arange(m, dtype=jnp.int32)
    w = jax.random.uniform(ks[2], (m,))

    def loss_k(q_, p_):
        lse, pos = fused_infonce_rows(q_, p_, labels, 1.0, 128, 128, True)
        return jnp.sum((lse - pos) * w)

    def loss_r(q_, p_):
        lse, pos = infonce_rows_ref(q_, p_, labels)
        return jnp.sum((lse - pos) * w)

    gk = jax.grad(loss_k, argnums=(0, 1))(q, p)
    gr = jax.grad(loss_r, argnums=(0, 1))(q, p)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=5e-6)


# ---------------------------------------------------------------- flash attention
@pytest.mark.parametrize(
    "b,sq,skv,h,hk,d,causal",
    [
        (2, 128, 128, 4, 4, 32, False),
        (2, 128, 128, 4, 4, 32, True),
        (1, 256, 256, 8, 2, 64, True),    # GQA 4:1
        (2, 64, 256, 4, 1, 32, False),    # MQA cross-length
    ],
)
def test_flash_attention_fwd_sweep(b, sq, skv, h, hk, d, causal):
    ks = jax.random.split(jax.random.PRNGKey(sq + skv + h), 4)
    q = jax.random.normal(ks[0], (b, sq, h, d))
    k = jax.random.normal(ks[1], (b, skv, hk, d))
    v = jax.random.normal(ks[2], (b, skv, hk, d))
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kv_mask_and_dtype(dtype):
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    b, s, h, d = 2, 128, 4, 32
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, h, d), dtype)
    v = jax.random.normal(ks[2], (b, s, h, d), dtype)
    mask = jax.random.bernoulli(ks[3], 0.7, (b, s)).at[:, 0].set(True)
    out = flash_attention(q, k, v, kv_mask=mask, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, kv_mask=mask)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_flash_attention_grads_match_plain():
    ks = jax.random.split(jax.random.PRNGKey(13), 3)
    b, s, h, d = 1, 128, 2, 32
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))

    def f_kernel(q_, k_, v_):
        return flash_attention(q_, k_, v_, causal=True, block_q=64, block_k=64).sum()

    def f_ref(q_, k_, v_):
        return flash_attention_ref(q_, k_, v_, causal=True).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------- embedding bag
@pytest.mark.parametrize(
    "v,d,l,n_bags",
    [(64, 128, 32, 8), (256, 128, 100, 10), (1000, 256, 17, 5)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_sweep(v, d, l, n_bags, dtype):
    ks = jax.random.split(jax.random.PRNGKey(v + l), 3)
    table = jax.random.normal(ks[0], (v, d), dtype)
    indices = jax.random.randint(ks[1], (l,), 0, v)
    # sorted non-decreasing bag ids covering all bags
    bag_ids = jnp.sort(jax.random.randint(ks[2], (l,), 0, n_bags))
    out = embedding_bag(table, indices, bag_ids, n_bags, True)
    ref = embedding_bag_ref(table, indices, bag_ids, n_bags)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_embedding_bag_grad_scatter():
    ks = jax.random.split(jax.random.PRNGKey(21), 3)
    v, d, l, n_bags = 32, 128, 16, 4
    table = jax.random.normal(ks[0], (v, d))
    indices = jax.random.randint(ks[1], (l,), 0, v)
    bag_ids = jnp.sort(jax.random.randint(ks[2], (l,), 0, n_bags))

    def f_kernel(t):
        return (embedding_bag(t, indices, bag_ids, n_bags, True) ** 2).sum()

    def f_ref(t):
        return (embedding_bag_ref(t, indices, bag_ids, n_bags) ** 2).sum()

    gk = jax.grad(f_kernel)(table)
    gr = jax.grad(f_ref)(table)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-5, atol=1e-6)


def test_embedding_bag_empty_bags_are_zero():
    table = jnp.ones((8, 128))
    indices = jnp.array([0, 1], jnp.int32)
    bag_ids = jnp.array([0, 3], jnp.int32)  # bags 1, 2 empty
    out = embedding_bag(table, indices, bag_ids, 4, True)
    np.testing.assert_array_equal(np.asarray(out[1]), np.zeros(128))
    np.testing.assert_array_equal(np.asarray(out[2]), np.zeros(128))
