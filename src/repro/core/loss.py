"""The canonical contrastive step loss shared by every update method.

Single loss assembly covering:
  - plain in-batch negatives (DPR / GradAccum / GradCache): no extras;
  - ContAccum's extended similarity matrix (paper Eq. 5-7): dual banks;
  - pre-batch negatives ablation: passage-only bank;
  - cross-device negatives: columns are all-gathered across the DP axes and
    each device reduces over its own rows (see core/dist.py).

The row-level softmax statistics are computed by a pluggable ``LossBackend``:

  * ``dense`` (default) — materializes the (M, N) logits block with one
    einsum; exact, simple, and fine while M*N fits comfortably in HBM.
  * ``fused`` — the blocked online-softmax Pallas kernel
    (kernels/fused_infonce): streams (block_m x block_n) tiles through VMEM,
    so the extended similarity matrix of ContAccum's dual banks (up to 128k
    columns at pod scale) never touches HBM, in either direction of the
    custom VJP. Gradient-exact vs ``dense`` to fp32 tolerance
    (tests/test_fused_infonce.py); runs under ``interpret=True`` on CPU so
    the whole method matrix is testable without a TPU.

Select with ``ContrastiveConfig.loss_impl`` (threaded through
``build_step_program`` and every NegativeSource) or pass ``backend=`` here
directly. Both backends honor the same contract: per-row ``lse - pos`` with
invalid columns masked exactly, arbitrary per-row weighting (ExtraRows), and
argmax accuracy.

Column assembly is *source-driven*: a NegativeSource (core/step_program.py)
describes where its negatives come from with two declarative blocks —
``ExtraColumns`` (extra similarity columns + validity mask) and ``ExtraRows``
(extra replicated query rows + their labels into the extra-column block) —
and ``contrastive_loss`` assembles the matrix. The legacy bank-taking entry
point ``contrastive_step_loss`` is a thin wrapper that converts dual banks
into those blocks.

Row/column layout (global view):

  rows    = [ global queries (B_g) ] ++ [ extra rows (R) ]
  columns = [ global positives (B_g) ] ++ [ global hard negs (B_g*H) ]
            ++ [ extra columns (C) ]

Labels: global query i -> column i; extra row j -> column
B_g*(1+H) + extra_rows.labels[j]. Invalid extra slots are masked exactly
(warm-up phase). In distributed mode a device owns its local query rows plus
a 1/D share of the (replicated) extra rows, so the psum over devices
reproduces the global row sum exactly once.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Protocol, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.dist import DistCtx
from repro.core.infonce import NEG_INF
from repro.core.memory_bank import BankState, aligned_valid, columns_view
from repro.core.precision import STATS_DTYPE, PrecisionPolicy, resolve_precision


class LossAux(NamedTuple):
    loss: jnp.ndarray          # global scalar loss (already psum'ed)
    accuracy: jnp.ndarray      # global accuracy over valid rows
    n_rows: jnp.ndarray        # global number of rows in the mean
    n_negatives: jnp.ndarray   # valid columns - 1 (negatives per query)
    q_global: jnp.ndarray      # gathered query reps (for bank push)
    p_global: jnp.ndarray      # gathered positive-passage reps (for bank push)


class ExtraColumns(NamedTuple):
    """Extra similarity columns owned by a negative source (e.g. a passage
    bank). ``valid`` masks slots exactly (False slots never enter the
    softmax).

    ``sharded=False`` (default): ``reps`` is the full (global) column block,
    present on every device. ``sharded=True``: ``reps`` is this device's
    ``C_global / D`` shard of a block laid out shard-major over the DP ring
    (shard s owns global columns ``[s*C_local, (s+1)*C_local)``), and the
    loss streams the shards around the ring (``loss_comm='ring'``) instead
    of all-gathering them — same math, ``O(C_global·d / D)`` peak transient
    memory."""

    reps: jnp.ndarray   # (C, d)
    valid: jnp.ndarray  # (C,) bool
    sharded: bool = False


class ExtraRows(NamedTuple):
    """Extra query rows owned by a negative source (e.g. a query bank).

    ``sharded=False`` (default): rows are replicated across devices; each
    device contributes a 1/D share so the psum reproduces their sum exactly
    once. ``sharded=True``: each device's rows are a distinct 1/D partition
    of the global row set (sharded memory banks) and enter the sum at full
    weight — the psum still counts every global row exactly once. ``labels``
    index into the source's ExtraColumns block *in its global (gathered)
    layout* (the loss adds the in-batch column offset). ``weight`` in [0, 1]
    scales each row's contribution (0 masks it out)."""

    reps: jnp.ndarray    # (R, d)
    labels: jnp.ndarray  # (R,) int32 — positive's index within ExtraColumns
    weight: jnp.ndarray  # (R,) float32
    sharded: bool = False


# --------------------------------------------------------------------------
# Loss backends: how the (rows x columns) softmax statistics are computed
# --------------------------------------------------------------------------
class LossBackend(Protocol):
    """Computes the per-row softmax statistics of one row block against the
    assembled column set. Implementations must agree to fp32 tolerance.

    Precision contract: ``q_rows``/``p_all`` may arrive in any float dtype
    (the PrecisionPolicy's compute dtype — bf16 under the ``bf16``/
    ``bf16_banks`` presets); every softmax statistic (logits, lse, pos,
    accuracy indicator) is computed and returned in fp32 (the policy's
    ``accum_dtype``) regardless, so low-precision inputs never degrade the
    statistics themselves (tests/test_precision.py pins this)."""

    name: str

    def row_stats(
        self,
        q_rows: jnp.ndarray,     # (M, d) query rows
        p_all: jnp.ndarray,      # (N, d) assembled columns
        labels: jnp.ndarray,     # (M,) int32 — positive column per row
        col_mask: jnp.ndarray,   # (N,) bool — invalid columns masked exactly
        *,
        temperature: float,
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (per_row_loss, correct): ``lse - pos`` per row
        (differentiable w.r.t. q_rows / p_all) and the stop-gradient
        argmax-accuracy indicator (backends may differ on exact logit
        ties — a measure-zero, metrics-only discrepancy)."""
        ...

    def chunk_stats(
        self,
        q_rows: jnp.ndarray,     # (M, d) query rows
        p_chunk: jnp.ndarray,    # (N_c, d) one chunk of the column set
        labels: jnp.ndarray,     # (M,) int32 — chunk-local, may be out of range
        col_mask: jnp.ndarray,   # (N_c,) bool
        *,
        temperature: float,
    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Per-chunk carried online-softmax state ``(lse, pos, amax)`` for the
        ring-streamed loss. ``labels`` are chunk-local indices; rows whose
        positive lies in another chunk carry out-of-range labels and must get
        ``pos = 0`` with zero gradient. Stats from disjoint chunks compose
        exactly via ``kernels.fused_infonce.ops.merge_row_stats``."""
        ...


class DenseLossBackend:
    """One einsum materializes the (M, N) logits block — the reference path."""

    name = "dense"

    def row_stats(self, q_rows, p_all, labels, col_mask, *, temperature):
        logits = jnp.einsum(
            "md,nd->mn", q_rows, p_all, preferred_element_type=jnp.float32
        ) / jnp.asarray(temperature, STATS_DTYPE)
        logits = jnp.where(col_mask[None, :], logits, NEG_INF)
        lse = jax.nn.logsumexp(logits, axis=-1)
        pos = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        correct = (jnp.argmax(logits, axis=-1) == labels).astype(STATS_DTYPE)
        return lse - pos, correct

    def chunk_stats(self, q_rows, p_chunk, labels, col_mask, *, temperature):
        logits = jnp.einsum(
            "md,nd->mn", q_rows, p_chunk, preferred_element_type=jnp.float32
        ) / jnp.asarray(temperature, STATS_DTYPE)
        logits = jnp.where(col_mask[None, :], logits, NEG_INF)
        lse = jax.nn.logsumexp(logits, axis=-1)
        n = p_chunk.shape[0]
        owns = (labels >= 0) & (labels < n)
        safe = jnp.clip(labels, 0, n - 1)
        pos = jnp.take_along_axis(logits, safe[:, None], axis=-1)[:, 0]
        # non-owning rows: pos = 0 and, via where, exactly zero gradient
        pos = jnp.where(owns, pos, jnp.zeros((), STATS_DTYPE))
        return lse, pos, jnp.max(logits, axis=-1)


@dataclasses.dataclass(frozen=True)
class FusedLossBackend:
    """Blocked online-softmax Pallas kernel (kernels/fused_infonce): the
    logits block lives tile-by-tile in VMEM, never in HBM. ``interpret=None``
    auto-selects: compiled on TPU, interpreter elsewhere (CPU-testable)."""

    block_m: int = 128
    block_n: int = 128
    interpret: Optional[bool] = None

    name = "fused"

    def row_stats(self, q_rows, p_all, labels, col_mask, *, temperature):
        from repro.kernels.fused_infonce.ops import fused_infonce_stats

        interpret = (
            jax.default_backend() != "tpu"
            if self.interpret is None
            else self.interpret
        )
        # q/p may be bf16 (compute dtype); the kernel casts block loads to a
        # common dtype and keeps all statistics + VJP accumulation in fp32
        lse, pos, amax = fused_infonce_stats(
            q_rows,
            p_all,
            labels.astype(jnp.int32),
            col_mask,
            1.0 / float(temperature),
            self.block_m,
            self.block_n,
            interpret,
        )
        # amax is metrics-only (its VJP cotangent is discarded by the kernel).
        # Tie semantics differ from dense on exact fp32 logit ties: here a
        # tied positive counts as correct, while dense argmax breaks ties by
        # column index — losses/gradients are unaffected.
        correct = jax.lax.stop_gradient((pos >= amax).astype(STATS_DTYPE))
        return lse - pos, correct

    def chunk_stats(self, q_rows, p_chunk, labels, col_mask, *, temperature):
        from repro.kernels.fused_infonce.ops import fused_infonce_stats

        interpret = (
            jax.default_backend() != "tpu"
            if self.interpret is None
            else self.interpret
        )
        # the kernel handles out-of-range labels natively: the one-hot select
        # never fires, so pos stays 0 with zero gradient — exactly the
        # non-owning-chunk contract
        return fused_infonce_stats(
            q_rows,
            p_chunk,
            labels.astype(jnp.int32),
            col_mask,
            1.0 / float(temperature),
            self.block_m,
            self.block_n,
            interpret,
        )


LOSS_BACKENDS = {"dense": DenseLossBackend, "fused": FusedLossBackend}

_DENSE_BACKEND = DenseLossBackend()


def resolve_loss_backend(
    spec: Union[None, str, LossBackend] = None,
) -> LossBackend:
    """None -> dense; a registered name -> fresh instance; an instance -> as
    is. Raises ValueError for unknown names (surfaced at program build)."""
    if spec is None:
        return _DENSE_BACKEND
    if isinstance(spec, str):
        if spec not in LOSS_BACKENDS:
            raise ValueError(
                f"unknown loss_impl {spec!r}; one of {sorted(LOSS_BACKENDS)}"
            )
        return LOSS_BACKENDS[spec]()
    return spec


def contrastive_loss(
    q_local: jnp.ndarray,
    p_pos_local: jnp.ndarray,
    p_hard_local: Optional[jnp.ndarray] = None,
    *,
    extra_cols: Optional[ExtraColumns] = None,
    extra_rows: Optional[ExtraRows] = None,
    temperature: float = 1.0,
    ctx: Optional[DistCtx] = None,
    backend: Union[None, str, LossBackend] = None,
    precision: Union[None, str, PrecisionPolicy] = None,
) -> tuple[jnp.ndarray, LossAux]:
    """Returns (loss_dev, aux). ``loss_dev`` is this device's share of the
    global loss: psum(loss_dev) == global loss; in single-device mode
    loss_dev == global loss. Differentiate loss_dev, then psum the grads.
    ``backend`` selects how the softmax statistics are computed (None ->
    dense einsum; 'fused' -> the blocked Pallas kernel; or an instance).
    ``precision`` (a PrecisionPolicy or preset name) is the single place the
    loss casts: the local representations are cast to ``compute_dtype`` here,
    and the extra column/row blocks (bank buffers, possibly in a narrower
    ``bank_dtype``) are cast to match — no call site needs ad-hoc ``.astype``.
    None keeps the incoming dtypes (fp32 legacy behavior, bit-identical).
    Softmax statistics and the row reductions stay fp32 either way.
    """
    ctx = ctx or DistCtx()
    be = resolve_loss_backend(backend)
    if precision is not None:
        pol = resolve_precision(precision)
        q_local = pol.cast_compute(q_local)
        p_pos_local = pol.cast_compute(p_pos_local)
        p_hard_local = pol.cast_compute(p_hard_local)
    b_local = q_local.shape[0]

    # --- columns (gathered across DP axes) ---
    p_pos = ctx.gather(p_pos_local)
    cols = [p_pos]
    if p_hard_local is not None and p_hard_local.shape[0] > 0:
        cols.append(ctx.gather(p_hard_local))
    b_g = p_pos.shape[0]
    n_hard = 0 if len(cols) == 1 else cols[1].shape[0]

    # ring mode: extra_cols carries only this device's bank shard; the global
    # extra block is the D shards streamed around the ring, never gathered
    ring = extra_cols is not None and extra_cols.sharded
    n_extra_local = 0 if extra_cols is None else extra_cols.reps.shape[0]
    n_extra = n_extra_local * ctx.device_count() if ring else n_extra_local
    if n_extra_local > 0 and not ring:
        cols.append(extra_cols.reps.astype(p_pos.dtype))
    p_all = jnp.concatenate(cols, axis=0)

    col_mask = jnp.ones((b_g + n_hard,), dtype=bool)
    if n_extra_local > 0 and not ring:
        col_mask = jnp.concatenate([col_mask, extra_cols.valid], axis=0)

    # --- local rows: this device's queries ---
    row_offset = ctx.shard_index() * b_local  # global index of local row 0
    labels_local = row_offset + jnp.arange(b_local, dtype=jnp.int32)

    have_extra_rows = (
        extra_rows is not None and extra_rows.reps.shape[0] > 0 and n_extra > 0
    )
    if have_extra_rows:
        labels_extra = (b_g + n_hard + extra_rows.labels.astype(jnp.int32)) % (
            b_g + n_hard + n_extra
        )
        w = extra_rows.weight.astype(STATS_DTYPE)
        # replicated rows: every device computes all R rows, each contributes
        # a 1/D share; sharded rows: the R local rows are this device's own
        # partition of the global set, so they enter at full weight
        inv_d = 1.0 if extra_rows.sharded else 1.0 / ctx.device_count()

    if ring:
        # evaluate local queries and (sharded) bank rows in one ring pass:
        # block A (the gathered in-batch columns) plus D rotating bank shards
        rows = [q_local]
        labels_all = [labels_local]
        if have_extra_rows:
            rows.append(extra_rows.reps.astype(q_local.dtype))
            labels_all.append(labels_extra)
        per_row, correct = _ring_row_stats(
            jnp.concatenate(rows, axis=0),
            jnp.concatenate(labels_all, axis=0),
            p_all,
            extra_cols,
            ctx,
            be,
            temperature=temperature,
        )
        loss_sum = per_row[:b_local].sum()
        correct_sum = correct[:b_local].sum()
        n_rows_dev = jnp.asarray(b_local, STATS_DTYPE)
        if have_extra_rows:
            loss_sum = loss_sum + inv_d * jnp.sum(per_row[b_local:] * w)
            correct_sum = correct_sum + inv_d * jnp.sum(correct[b_local:] * w)
            n_rows_dev = n_rows_dev + inv_d * w.sum()
        # the global column mask never materializes: count valid bank slots
        # with a psum over the shards instead
        n_cols_valid = jnp.asarray(b_g + n_hard, STATS_DTYPE) + ctx.psum(
            extra_cols.valid.sum().astype(STATS_DTYPE)
        )
    else:
        def row_stats(q_rows, labels):
            return be.row_stats(
                q_rows, p_all, labels, col_mask, temperature=temperature
            )

        per_row_local, correct_local = row_stats(q_local, labels_local)
        loss_sum = per_row_local.sum()
        correct_sum = correct_local.sum()
        n_rows_dev = jnp.asarray(b_local, STATS_DTYPE)

        # --- extra rows (replicated; each device takes a 1/D share) ---
        if have_extra_rows:
            per_row_extra, correct_extra = row_stats(
                extra_rows.reps.astype(q_local.dtype), labels_extra
            )
            loss_sum = loss_sum + inv_d * jnp.sum(per_row_extra * w)
            correct_sum = correct_sum + inv_d * jnp.sum(correct_extra * w)
            n_rows_dev = n_rows_dev + inv_d * w.sum()
        n_cols_valid = col_mask.sum().astype(STATS_DTYPE)

    n_rows_g = jax.lax.stop_gradient(ctx.psum(n_rows_dev))
    n_rows_g = jnp.maximum(n_rows_g, 1.0)
    loss_dev = loss_sum / n_rows_g

    aux = LossAux(
        loss=jax.lax.stop_gradient(ctx.psum(loss_dev)),
        accuracy=jax.lax.stop_gradient(ctx.psum(correct_sum) / n_rows_g),
        n_rows=n_rows_g,
        n_negatives=n_cols_valid - 1.0,
        q_global=jax.lax.stop_gradient(ctx.gather(q_local)),
        p_global=jax.lax.stop_gradient(p_pos),
    )
    return loss_dev, aux


def _ring_row_stats(
    q_rows: jnp.ndarray,
    labels: jnp.ndarray,
    p_inbatch: jnp.ndarray,
    extra_cols: ExtraColumns,
    ctx: DistCtx,
    be: LossBackend,
    *,
    temperature: float,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Ring-streamed (per_row_loss, correct) over the full global column set
    [in-batch block (b_g + n_hard)] ++ [bank shard 0] ++ ... ++ [shard D-1]
    without ever materializing more than one ``C_local``-column bank chunk per
    device. ``labels`` are global column indices.

    Each of the 1 + D chunk evaluations produces the backend's carried
    online-softmax state ``(lse, pos, amax)``; ``merge_row_stats`` composes
    them into the exact full-set statistics. The bank shard (reps in the
    bank's storage dtype + validity mask) hops the DP ring D-1 times via
    ``DistCtx.ring_rotate``: at hop k device i holds shard ``(i - k) mod D``,
    whose global column offset positions its chunk-local labels. Peak
    transient memory for the extra block is ``O(C_local·d) = O(C_global·d/D)``
    vs the all-gather path's ``O(C_global·d)``.

    Backward pass: the merge's chain rule scales each chunk's lse cotangent
    by ``exp(lse_k - lse)``, making every chunk-local softmax coefficient
    global; dQ accumulates locally across the chunk calls, and any dP
    cotangent written against a visiting shard rides ppermute's transpose
    (the inverse rotation) back to the owning device. Bank buffers are
    stop_gradient'd at push, so in practice the reverse ring carries zeros —
    but the path is exact regardless.

    Accuracy uses the fused kernel's tie semantics (``pos >= amax``) for both
    backends — on exact fp32 logit ties a tied positive counts as correct,
    a measure-zero metrics-only difference from dense argmax.
    """
    n_a = p_inbatch.shape[0]

    lse_a, pos_a, amax_a = be.chunk_stats(
        q_rows, p_inbatch, labels, jnp.ones((n_a,), dtype=bool),
        temperature=temperature,
    )
    owns_a = (labels >= 0) & (labels < n_a)
    lse_s, pos_s, owns_s, amax_s = _stream_bank_chunks(
        ctx, be, n_a, temperature, q_rows, labels,
        extra_cols.reps, extra_cols.valid,
    )

    from repro.kernels.fused_infonce.ops import merge_row_stats

    lse, pos, amax = merge_row_stats(
        jnp.concatenate([lse_a[None], lse_s], axis=0),
        jnp.concatenate([pos_a[None], pos_s], axis=0),
        jnp.concatenate([owns_a[None], owns_s], axis=0),
        jnp.concatenate([amax_a[None], amax_s], axis=0),
    )
    correct = jax.lax.stop_gradient((pos >= amax).astype(STATS_DTYPE))
    return lse - pos, correct


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _stream_bank_chunks(ctx, be, n_a, temperature, q_rows, labels, reps, valid):
    """Per-chunk stats ``(lse, pos, owns, amax)``, each stacked (D, M), for
    the D bank shards streamed around the DP ring — with a **reverse-streamed
    backward pass**. Plain AD through the rotation loop would save every
    visiting shard as a residual (all D alive at once — O(N_mem*d) again,
    exactly what the ring exists to avoid); the custom VJP instead saves only
    this device's own shard and re-streams the ring during the backward pass,
    recomputing each chunk's forward on the fly (jax.vjp), so at most one
    N_mem/D chunk is resident in either direction. dQ accumulates locally
    across the hops; each visiting shard's dP cotangent accumulates in a
    buffer that travels *with* the shard and is delivered home by the final
    rotation (ppermute's transpose semantics, done by hand here).
    """
    out, _ = _stream_fwd(ctx, be, n_a, temperature, q_rows, labels, reps, valid)
    return out


def _stream_chunk_eval(be, q_rows, labels, reps, valid, offset, *, temperature):
    local_labels = labels - offset
    lse, pos, amax = be.chunk_stats(
        q_rows, reps.astype(q_rows.dtype), local_labels, valid,
        temperature=temperature,
    )
    owns = (local_labels >= 0) & (local_labels < reps.shape[0])
    return lse, pos, owns, amax


def _stream_fwd(ctx, be, n_a, temperature, q_rows, labels, reps, valid):
    d_ring = ctx.device_count()
    cap_local = reps.shape[0]
    sidx = ctx.shard_index()

    # lax.scan (not a Python loop) so the rotating shard is a loop *carry*:
    # one ping-pong buffer regardless of D. An unrolled loop emits D distinct
    # collective-permute results whose buffers stay concurrently live in the
    # compiled program — summing back to the full O(N_mem*d) footprint the
    # ring exists to avoid.
    def hop(shard, k):
        # after k hops of the (i -> i+1) rotation, device i holds the shard
        # pushed by device (i - k) mod D, i.e. global bank columns
        # [owner*cap_local, (owner+1)*cap_local)
        owner = (sidx - k) % d_ring
        reps_k, valid_k = shard
        stats = _stream_chunk_eval(
            be, q_rows, labels, reps_k, valid_k,
            n_a + owner * cap_local, temperature=temperature,
        )
        # rotate the raw storage-dtype buffer: minimal bytes on the wire.
        # Rotating every iteration keeps the scan body uniform; the final
        # hop returns the shard to its owner.
        return ctx.ring_rotate(shard), stats

    _, out = jax.lax.scan(hop, (reps, valid), jnp.arange(d_ring))
    # residuals: this device's own shard only — the visiting shards are
    # re-streamed (recomputed by a second pass around the ring) in _stream_bwd
    return out, (q_rows, labels, reps, valid)


def _stream_bwd(ctx, be, n_a, temperature, res, cotangents):
    q_rows, labels, reps, valid = res
    g_lse, g_pos, _, _ = cotangents  # owns is bool, amax metrics-only
    d_ring = ctx.device_count()
    cap_local = reps.shape[0]
    sidx = ctx.shard_index()

    def hop(carry, inp):
        (reps_k, valid_k), d_reps_k, dq = carry
        k, g_lse_k, g_pos_k = inp
        owner = (sidx - k) % d_ring

        def f(qr, pc):
            lse, pos, _, amax = _stream_chunk_eval(
                be, qr, labels, pc, valid_k,
                n_a + owner * cap_local, temperature=temperature,
            )
            return lse, pos, amax

        # recompute this chunk's forward on the fly (the fwd saved only the
        # local shard): at most one visiting shard plus its cotangent buffer
        # is resident at a time
        _, vjp_fn = jax.vjp(f, q_rows, reps_k)
        dq_k, dp_k = vjp_fn((g_lse_k, g_pos_k, jnp.zeros_like(g_lse_k)))
        # the shard's cotangent buffer travels *with* the shard: every
        # device deposits its contribution as the pair passes through, and
        # the final hop (k = D-1) delivers the accumulated dP to its owner
        rotated = ctx.ring_rotate(
            ((reps_k, valid_k), d_reps_k + dp_k.astype(d_reps_k.dtype))
        )
        return rotated + (dq + dq_k.astype(dq.dtype),), None

    carry0 = (
        (reps, valid),
        jnp.zeros_like(reps),
        jnp.zeros(q_rows.shape, STATS_DTYPE),
    )
    (_, d_reps, dq), _ = jax.lax.scan(
        hop, carry0, (jnp.arange(d_ring), g_lse, g_pos)
    )
    return dq.astype(q_rows.dtype), None, d_reps, None


_stream_bank_chunks.defvjp(_stream_fwd, _stream_bwd)


def bank_extra_columns(bank_p: Optional[BankState]) -> Optional[ExtraColumns]:
    """Passage bank -> extra similarity columns (None when disabled)."""
    if bank_p is None or bank_p.buf.shape[0] == 0:
        return None
    reps, valid = columns_view(bank_p)
    return ExtraColumns(reps=reps, valid=valid)


def bank_extra_rows(
    bank_q: Optional[BankState], bank_p: Optional[BankState]
) -> Optional[ExtraRows]:
    """Dual banks -> extra query rows labeled with their lockstep-aligned
    positives in the passage bank (None unless both banks are enabled)."""
    if bank_q is None or bank_q.buf.shape[0] == 0:
        return None
    if bank_p is None or bank_p.buf.shape[0] == 0:
        return None
    cq = bank_q.buf.shape[0]
    return ExtraRows(
        reps=bank_q.buf,
        labels=jnp.arange(cq, dtype=jnp.int32),
        weight=aligned_valid(bank_q, bank_p).astype(STATS_DTYPE),
    )


def sharded_bank_extra_columns(
    bank_p: Optional[BankState], ctx: DistCtx, comm: str = "all_gather"
) -> Optional[ExtraColumns]:
    """Shard-local passage bank -> extra columns, under the selected
    communication strategy (``ContrastiveConfig.loss_comm``):

    * ``"all_gather"`` — rows and validity are all-gathered over the DP axes
      into the *global* block (shard-major concatenation matches the bank's
      global ring layout — see memory_bank.shard_push). Transient memory per
      loss eval is O(N_mem*d) regardless of D.
    * ``"ring"`` — the shard stays local (``sharded=True``) and the loss
      streams the D shards around the DP ring with ppermute + online-softmax
      merges: same math, O(N_mem*d/D) transient memory. Falls back to the
      gather in single-device mode (where the shard already *is* the bank).
    """
    if bank_p is None or bank_p.buf.shape[0] == 0:
        return None
    if comm == "ring" and ctx.is_distributed:
        return ExtraColumns(reps=bank_p.buf, valid=bank_p.valid, sharded=True)
    return ExtraColumns(reps=ctx.gather(bank_p.buf), valid=ctx.gather(bank_p.valid))


def sharded_bank_extra_rows(
    bank_q: Optional[BankState], bank_p: Optional[BankState], ctx: DistCtx
) -> Optional[ExtraRows]:
    """Shard-local dual banks -> this device's partition of the extra query
    rows. No gather is needed: each device evaluates only its own bank rows
    (labels offset into the gathered column block by the shard's global slot
    offset), and the psum sums every global row exactly once."""
    if bank_q is None or bank_q.buf.shape[0] == 0:
        return None
    if bank_p is None or bank_p.buf.shape[0] == 0:
        return None
    cap_local = bank_q.buf.shape[0]
    offset = jnp.asarray(ctx.shard_index(), jnp.int32) * cap_local
    return ExtraRows(
        reps=bank_q.buf,
        labels=offset + jnp.arange(cap_local, dtype=jnp.int32),
        weight=aligned_valid(bank_q, bank_p).astype(STATS_DTYPE),
        sharded=True,
    )


def contrastive_step_loss(
    q_local: jnp.ndarray,
    p_pos_local: jnp.ndarray,
    p_hard_local: Optional[jnp.ndarray],
    bank_q: Optional[BankState],
    bank_p: Optional[BankState],
    *,
    temperature: float = 1.0,
    ctx: Optional[DistCtx] = None,
    backend: Union[None, str, LossBackend] = None,
    precision: Union[None, str, PrecisionPolicy] = None,
) -> tuple[jnp.ndarray, LossAux]:
    """Legacy bank-taking entry point: dual banks -> extras -> loss."""
    return contrastive_loss(
        q_local,
        p_pos_local,
        p_hard_local,
        extra_cols=bank_extra_columns(bank_p),
        extra_rows=bank_extra_rows(bank_q, bank_p),
        temperature=temperature,
        ctx=ctx,
        backend=backend,
        precision=precision,
    )
