"""The canonical contrastive step loss shared by every update method.

Single implementation covering:
  - plain in-batch negatives (DPR / GradAccum / GradCache): empty banks;
  - ContAccum's extended similarity matrix (paper Eq. 5-7): dual banks;
  - pre-batch negatives ablation: passage-only bank;
  - cross-device negatives: columns are all-gathered across the DP axes and
    each device reduces over its own rows (see core/dist.py).

Row/column layout (global view):

  rows    = [ global queries (B_g) ] ++ [ bank queries (Cq) ]
  columns = [ global positives (B_g) ] ++ [ global hard negs (B_g*H) ]
            ++ [ bank passages (Cp) ]

Labels: global query i -> column i; bank query j -> column B_g*(1+H) + j.
Invalid bank slots are masked exactly (warm-up phase). In distributed mode a
device owns its local query rows plus a 1/D share of the (replicated) bank
rows, so the psum over devices reproduces the global row sum exactly once.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.dist import DistCtx
from repro.core.infonce import NEG_INF
from repro.core.memory_bank import BankState


class LossAux(NamedTuple):
    loss: jnp.ndarray          # global scalar loss (already psum'ed)
    accuracy: jnp.ndarray      # global accuracy over valid rows
    n_rows: jnp.ndarray        # global number of rows in the mean
    n_negatives: jnp.ndarray   # valid columns - 1 (negatives per query)
    q_global: jnp.ndarray      # gathered query reps (for bank push)
    p_global: jnp.ndarray      # gathered positive-passage reps (for bank push)


def contrastive_step_loss(
    q_local: jnp.ndarray,
    p_pos_local: jnp.ndarray,
    p_hard_local: Optional[jnp.ndarray],
    bank_q: Optional[BankState],
    bank_p: Optional[BankState],
    *,
    temperature: float = 1.0,
    ctx: Optional[DistCtx] = None,
) -> tuple[jnp.ndarray, LossAux]:
    """Returns (loss_dev, aux). ``loss_dev`` is this device's share of the
    global loss: psum(loss_dev) == global loss; in single-device mode
    loss_dev == global loss. Differentiate loss_dev, then psum the grads.
    """
    ctx = ctx or DistCtx()
    b_local = q_local.shape[0]

    # --- columns (gathered across DP axes) ---
    p_pos = ctx.gather(p_pos_local)
    cols = [p_pos]
    if p_hard_local is not None and p_hard_local.shape[0] > 0:
        cols.append(ctx.gather(p_hard_local))
    b_g = p_pos.shape[0]
    n_hard = 0 if len(cols) == 1 else cols[1].shape[0]

    cq = 0 if bank_q is None else bank_q.buf.shape[0]
    cp = 0 if bank_p is None else bank_p.buf.shape[0]
    if cp > 0:
        cols.append(bank_p.buf.astype(p_pos.dtype))
    p_all = jnp.concatenate(cols, axis=0)

    col_mask = jnp.ones((b_g + n_hard,), dtype=bool)
    if cp > 0:
        col_mask = jnp.concatenate([col_mask, bank_p.valid], axis=0)

    # --- local rows: this device's queries ---
    row_offset = ctx.shard_index() * b_local  # global index of local row 0
    labels_local = row_offset + jnp.arange(b_local, dtype=jnp.int32)

    def row_stats(q_rows, labels):
        logits = jnp.einsum(
            "md,nd->mn", q_rows, p_all, preferred_element_type=jnp.float32
        ) / jnp.asarray(temperature, jnp.float32)
        logits = jnp.where(col_mask[None, :], logits, NEG_INF)
        lse = jax.nn.logsumexp(logits, axis=-1)
        pos = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
        return lse - pos, correct

    per_row_local, correct_local = row_stats(q_local, labels_local)
    loss_sum = per_row_local.sum()
    correct_sum = correct_local.sum()
    n_rows_dev = jnp.asarray(b_local, jnp.float32)

    # --- bank-query rows (replicated; each device takes a 1/D share) ---
    if cq > 0 and cp > 0:
        c_align = min(cq, cp)
        labels_bank = (b_g + n_hard + jnp.arange(cq, dtype=jnp.int32)) % (
            b_g + n_hard + cp
        )
        per_row_bank, correct_bank = row_stats(
            bank_q.buf.astype(q_local.dtype), labels_bank
        )
        aligned = jnp.zeros((cq,), dtype=bool)
        aligned = aligned.at[:c_align].set(bank_q.valid[:c_align] & bank_p.valid[:c_align])
        w = aligned.astype(jnp.float32)
        inv_d = 1.0 / ctx.device_count()
        loss_sum = loss_sum + inv_d * jnp.sum(per_row_bank * w)
        correct_sum = correct_sum + inv_d * jnp.sum(correct_bank * w)
        n_rows_dev = n_rows_dev + inv_d * w.sum()

    n_rows_g = jax.lax.stop_gradient(ctx.psum(n_rows_dev))
    n_rows_g = jnp.maximum(n_rows_g, 1.0)
    loss_dev = loss_sum / n_rows_g

    aux = LossAux(
        loss=jax.lax.stop_gradient(ctx.psum(loss_dev)),
        accuracy=jax.lax.stop_gradient(ctx.psum(correct_sum) / n_rows_g),
        n_rows=n_rows_g,
        n_negatives=col_mask.sum().astype(jnp.float32) - 1.0,
        q_global=jax.lax.stop_gradient(ctx.gather(q_local)),
        p_global=jax.lax.stop_gradient(p_pos),
    )
    return loss_dev, aux
