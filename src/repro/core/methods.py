"""The paper's training methods behind one switch — now a thin registry over
`StepProgram` compositions (core/step_program.py).

Each ``method=`` string names a (negative source x backprop strategy) pair:

  - ``dpr``        : direct x in-batch — full-batch InfoNCE (the paper's
                     high-resource baseline).
  - ``grad_accum`` : scan-accumulate x in-batch — K local chunks, loss per
                     chunk (Eq. 4), fewer negatives.
  - ``grad_cache`` : rep-cache VJP x in-batch — decomposed backprop (Gao et
                     al. 2021); gradients are *exactly* the full-batch
                     gradients (tested).
  - ``contaccum``  : scan-accumulate x dual-bank — the paper's contribution:
                     GradAccum + dual FIFO memory banks extending the
                     similarity matrix (Eq. 5-7).
  - ``contcache``  : rep-cache VJP x dual-bank — exact full-batch backprop
                     *and* bank-extended negatives.
  - ``prebatch``   : scan-accumulate x passage-bank (pre-batch ablation).
  - ``prebatch_cache``: rep-cache VJP x passage-bank.
  - ``dpr_xdev``   : direct x cross-device-gathered in-batch negatives.

Every builder returns ``update(state, batch) -> (state, StepMetrics)``; all
are pure and jit/shard_map-compatible. Prefer ``build_step_program`` for the
full program handle (source/strategy introspection); ``make_update_fn`` and
the per-method ``make_*_update`` builders remain as the legacy surface.

Every method also honors ``cfg.loss_impl`` ('dense' | 'fused') — the loss
backend switch (core/loss.py) between the einsum logits block and the
blocked online-softmax Pallas kernel.
"""

from __future__ import annotations

import dataclasses

from repro.core.step_program import (  # noqa: F401  (re-exported API)
    COMPOSITIONS,
    SOURCES,
    STRATEGIES,
    StepProgram,
    available_methods,
    build_step_program,
    init_state,
    method_composition,
    method_needs_mesh,
    method_uses_banks,
)
from repro.core.types import ContrastiveConfig, DualEncoder
from repro.optim.adamw import GradientTransformation


def make_update_fn(encoder: DualEncoder, tx: GradientTransformation, cfg: ContrastiveConfig):
    """Factory: the registered methods behind one switch."""
    return build_step_program(encoder, tx, cfg).update


def _fixed_method(method: str):
    def make(encoder: DualEncoder, tx, cfg: ContrastiveConfig):
        cfg = dataclasses.replace(cfg, method=method, negatives=None, backprop=None)
        return make_update_fn(encoder, tx, cfg)

    make.__name__ = f"make_{method}_update"
    make.__doc__ = f"Legacy per-method builder: forces method={method!r}."
    return make


make_dpr_update = _fixed_method("dpr")
make_grad_accum_update = _fixed_method("grad_accum")
make_grad_cache_update = _fixed_method("grad_cache")
make_contaccum_update = _fixed_method("contaccum")
