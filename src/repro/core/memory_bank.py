"""Dual FIFO memory banks for ContAccum (paper Sec. 3.2, Fig. 2).

Pure-functional ring buffers with static shapes so they live inside jitted
train steps and checkpoints. ``valid`` masks make the warm-up phase (bank not
yet full) exact: unfilled slots are excluded from the softmax and from the
row mean — no approximation, no special cases in the loss.

The *dual* structure (equal-size query and passage banks, pushed in lockstep)
is the paper's core stability contribution: Sec. 3.3 shows that a
passage-only bank (pre-batch negatives) yields a systematic gradient-norm
imbalance between the two encoders.

Two distribution modes (core/step_program.py, ``cfg.shard_banks``):

  * **replicated** (default) — every device carries the full ring and pushes
    the gathered global rows (``push`` / ``push_pair``); banks stay identical
    across devices.
  * **sharded** — each device owns a ``capacity/D`` contiguous block of ring
    slots, laid out shard-major so ``DistCtx.gather`` over the shards
    reconstructs the replicated ring exactly (``shard_push`` /
    ``shard_push_pair``; ``bank_spec`` gives the PartitionSpecs). Per-device
    bank HBM shrinks by 1/D at identical math.

Precision: the ring buffers are stored in the PrecisionPolicy's
``bank_dtype`` (core/precision.py; ``init_bank``'s dtype is plumbed from
``ContrastiveConfig.resolved_bank_dtype()``). All casts are centralized —
pushes cast incoming rows to the buffer dtype here (``push``/``shard_push``),
and the loss casts buffer reads back to its compute dtype
(core/loss.py ``contrastive_loss``); no call site carries ad-hoc ``.astype``.
With ``bank_dtype=bf16`` the persistent per-device bank bytes halve again on
top of sharding: (N_q + N_p) * d * 2 / D.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision import resolve_precision


class BankState(NamedTuple):
    buf: jnp.ndarray    # (capacity, d) stored representations
    valid: jnp.ndarray  # (capacity,) bool — slot holds a real representation
    head: jnp.ndarray   # () int32 — next write position (ring)
    age: jnp.ndarray    # (capacity,) int32 — step counter at push time (diagnostics)


def init_bank(capacity: int, dim: int, dtype=None) -> BankState:
    if dtype is None:
        dtype = resolve_precision(None).bank_dtype
    return BankState(
        buf=jnp.zeros((capacity, dim), dtype=dtype),
        valid=jnp.zeros((capacity,), dtype=bool),
        head=jnp.zeros((), dtype=jnp.int32),
        age=jnp.zeros((capacity,), dtype=jnp.int32),
    )


def push(bank: BankState, x: jnp.ndarray, step: jnp.ndarray | int = 0) -> BankState:
    """Enqueue rows of ``x`` (n, d), dequeueing the oldest when full.

    ``x`` is stored with stop_gradient: bank entries never carry activations
    (paper Eq. 5-6, sg(.)). n may exceed capacity; the last ``capacity`` rows
    win, matching FIFO semantics. Oversized pushes are pre-sliced to those
    final ``capacity`` rows before the scatter — ``.at[idx].set`` with
    duplicate ring indices does not guarantee last-write-wins.
    """
    x = jax.lax.stop_gradient(x)
    n = x.shape[0]
    cap = bank.buf.shape[0]
    if n == 0 or cap == 0:
        return bank
    start = bank.head
    if n > cap:
        x = x[n - cap :]
        start = bank.head + (n - cap)
        n = cap
    idx = (start + jnp.arange(n, dtype=jnp.int32)) % cap
    buf = bank.buf.at[idx].set(x.astype(bank.buf.dtype))
    valid = bank.valid.at[idx].set(True)
    age = bank.age.at[idx].set(jnp.asarray(step, dtype=jnp.int32))
    head = (start + n) % cap
    return BankState(buf=buf, valid=valid, head=head, age=age)


def clear(bank: BankState) -> BankState:
    """Invalidate all slots (used by the 'w/o past encoder' ablation: banks are
    cleared at every optimizer-update boundary so only current-encoder
    representations are ever used)."""
    return BankState(
        buf=bank.buf,
        valid=jnp.zeros_like(bank.valid),
        head=jnp.zeros_like(bank.head),
        age=jnp.zeros_like(bank.age),
    )


def n_valid(bank: BankState) -> jnp.ndarray:
    return bank.valid.sum()


def push_pair(
    bank_q: BankState,
    bank_p: BankState,
    q: jnp.ndarray,
    p: jnp.ndarray,
    step: jnp.ndarray | int = 0,
) -> Tuple[BankState, BankState]:
    """Push query/passage representations in lockstep so ring positions align;
    bank row i in M_q is always the query whose positive passage is bank row i
    in M_p (required for the extended-loss label alignment)."""
    assert q.shape[0] == p.shape[0], "dual banks must be pushed in lockstep"
    return push(bank_q, q, step), push(bank_p, p, step)


def shard_push(
    bank: BankState,
    x: jnp.ndarray,
    step: jnp.ndarray | int = 0,
    *,
    shard_index,
    num_shards: int,
) -> BankState:
    """Shard-local ``push``: write only this device's rows of a globally
    ring-addressed enqueue.

    ``bank`` is the local ``capacity_global / num_shards`` shard of a global
    ring laid out shard-major (shard i owns global slots
    ``[i*cap_local, (i+1)*cap_local)`` — the same order ``DistCtx.gather``
    concatenates shards in). ``x`` is the full replicated global row block
    (every device sees the same gathered representations) and ``bank.head``
    is the replicated *global* head, so all shards advance it identically.
    The union of all shards after a shard_push is bit-identical to a
    replicated ``push`` of the same rows (tests/test_memory_bank.py)."""
    x = jax.lax.stop_gradient(x)
    n = x.shape[0]
    cap_local = bank.buf.shape[0]
    cap_global = cap_local * num_shards
    if n == 0 or cap_local == 0:
        return bank
    start = bank.head
    if n > cap_global:
        x = x[n - cap_global :]
        start = bank.head + (n - cap_global)
        n = cap_global
    gidx = (start + jnp.arange(n, dtype=jnp.int32)) % cap_global
    lidx = gidx - jnp.asarray(shard_index, jnp.int32) * cap_local
    # rows owned by other shards are pushed out of range; mode="drop"
    # discards them (cap_local itself is out of bounds for a (cap_local,)
    # buffer)
    lidx = jnp.where((lidx >= 0) & (lidx < cap_local), lidx, cap_local)
    buf = bank.buf.at[lidx].set(x.astype(bank.buf.dtype), mode="drop")
    valid = bank.valid.at[lidx].set(True, mode="drop")
    age = bank.age.at[lidx].set(jnp.asarray(step, dtype=jnp.int32), mode="drop")
    head = (start + n) % cap_global
    return BankState(buf=buf, valid=valid, head=head, age=age)


def shard_push_pair(
    bank_q: BankState,
    bank_p: BankState,
    q: jnp.ndarray,
    p: jnp.ndarray,
    step: jnp.ndarray | int = 0,
    *,
    shard_index,
    num_shards: int,
) -> Tuple[BankState, BankState]:
    """Lockstep ``shard_push`` of both banks (see push_pair)."""
    assert q.shape[0] == p.shape[0], "dual banks must be pushed in lockstep"
    kw = dict(shard_index=shard_index, num_shards=num_shards)
    return shard_push(bank_q, q, step, **kw), shard_push(bank_p, p, step, **kw)


def bank_spec(axes=None) -> BankState:
    """BankState-shaped PartitionSpecs: rows (buf/valid/age) sharded over
    ``axes`` (a mesh-axis name or tuple of names), the global head replicated.
    ``axes=None`` returns the fully replicated spec (the default mode where
    every device carries the whole bank)."""
    from jax.sharding import PartitionSpec as P

    row = P() if axes is None else P(tuple(axes) if not isinstance(axes, str) else axes)
    return BankState(buf=row, valid=row, head=P(), age=row)


def capacity(bank: BankState) -> int:
    """Static capacity of the ring (0 for a disabled bank)."""
    return bank.buf.shape[0]


def columns_view(bank: BankState) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(reps, valid) of a bank used as extra similarity *columns*.

    Source-facing helper: NegativeSource implementations hand this to the loss
    as an ``ExtraColumns`` block; order is irrelevant for columns, so no roll.
    """
    return bank.buf, bank.valid


def aligned_valid(bank_q: BankState, bank_p: BankState) -> jnp.ndarray:
    """(cq,) bool — slots where bank_q row i and bank_p row i hold an aligned
    (query, positive-passage) pair. Pushed-in-lockstep banks (push_pair) are
    aligned by ring index only when the capacities are equal: heads advance
    mod their own capacity, so with ``cq != cp`` the pairing silently breaks
    as soon as either ring wraps. Unequal non-zero capacities are therefore
    rejected; a disabled bank (capacity 0, the pre-batch ablation) yields no
    aligned rows."""
    cq, cp = bank_q.buf.shape[0], bank_p.buf.shape[0]
    if cq == 0 or cp == 0:
        return jnp.zeros((cq,), dtype=bool)
    if cq != cp:
        raise ValueError(
            f"dual banks must have equal capacities to stay ring-aligned "
            f"(got bank_q capacity {cq} != bank_p capacity {cp}); after a "
            f"ring wrap row i of M_q no longer pairs with row i of M_p"
        )
    return bank_q.valid & bank_p.valid


def ordered(bank: BankState) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(buf, valid) rolled so index 0 is the oldest entry. Only needed by
    diagnostics (similarity-mass, Appendix C) — the loss itself is
    order-independent given aligned banks."""
    cap = bank.buf.shape[0]
    perm = (bank.head + jnp.arange(cap, dtype=jnp.int32)) % cap
    return bank.buf[perm], bank.valid[perm]
