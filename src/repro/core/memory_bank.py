"""Dual FIFO memory banks for ContAccum (paper Sec. 3.2, Fig. 2).

Pure-functional ring buffers with static shapes so they live inside jitted
train steps and checkpoints. ``valid`` masks make the warm-up phase (bank not
yet full) exact: unfilled slots are excluded from the softmax and from the
row mean — no approximation, no special cases in the loss.

The *dual* structure (equal-size query and passage banks, pushed in lockstep)
is the paper's core stability contribution: Sec. 3.3 shows that a
passage-only bank (pre-batch negatives) yields a systematic gradient-norm
imbalance between the two encoders.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class BankState(NamedTuple):
    buf: jnp.ndarray    # (capacity, d) stored representations
    valid: jnp.ndarray  # (capacity,) bool — slot holds a real representation
    head: jnp.ndarray   # () int32 — next write position (ring)
    age: jnp.ndarray    # (capacity,) int32 — step counter at push time (diagnostics)


def init_bank(capacity: int, dim: int, dtype=jnp.float32) -> BankState:
    return BankState(
        buf=jnp.zeros((capacity, dim), dtype=dtype),
        valid=jnp.zeros((capacity,), dtype=bool),
        head=jnp.zeros((), dtype=jnp.int32),
        age=jnp.zeros((capacity,), dtype=jnp.int32),
    )


def push(bank: BankState, x: jnp.ndarray, step: jnp.ndarray | int = 0) -> BankState:
    """Enqueue rows of ``x`` (n, d), dequeueing the oldest when full.

    ``x`` is stored with stop_gradient: bank entries never carry activations
    (paper Eq. 5-6, sg(.)). n may exceed capacity; the last ``capacity`` rows
    win, matching FIFO semantics. Oversized pushes are pre-sliced to those
    final ``capacity`` rows before the scatter — ``.at[idx].set`` with
    duplicate ring indices does not guarantee last-write-wins.
    """
    x = jax.lax.stop_gradient(x)
    n = x.shape[0]
    cap = bank.buf.shape[0]
    if n == 0 or cap == 0:
        return bank
    start = bank.head
    if n > cap:
        x = x[n - cap :]
        start = bank.head + (n - cap)
        n = cap
    idx = (start + jnp.arange(n, dtype=jnp.int32)) % cap
    buf = bank.buf.at[idx].set(x.astype(bank.buf.dtype))
    valid = bank.valid.at[idx].set(True)
    age = bank.age.at[idx].set(jnp.asarray(step, dtype=jnp.int32))
    head = (start + n) % cap
    return BankState(buf=buf, valid=valid, head=head, age=age)


def clear(bank: BankState) -> BankState:
    """Invalidate all slots (used by the 'w/o past encoder' ablation: banks are
    cleared at every optimizer-update boundary so only current-encoder
    representations are ever used)."""
    return BankState(
        buf=bank.buf,
        valid=jnp.zeros_like(bank.valid),
        head=jnp.zeros_like(bank.head),
        age=jnp.zeros_like(bank.age),
    )


def n_valid(bank: BankState) -> jnp.ndarray:
    return bank.valid.sum()


def push_pair(
    bank_q: BankState,
    bank_p: BankState,
    q: jnp.ndarray,
    p: jnp.ndarray,
    step: jnp.ndarray | int = 0,
) -> Tuple[BankState, BankState]:
    """Push query/passage representations in lockstep so ring positions align;
    bank row i in M_q is always the query whose positive passage is bank row i
    in M_p (required for the extended-loss label alignment)."""
    assert q.shape[0] == p.shape[0], "dual banks must be pushed in lockstep"
    return push(bank_q, q, step), push(bank_p, p, step)


def capacity(bank: BankState) -> int:
    """Static capacity of the ring (0 for a disabled bank)."""
    return bank.buf.shape[0]


def columns_view(bank: BankState) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(reps, valid) of a bank used as extra similarity *columns*.

    Source-facing helper: NegativeSource implementations hand this to the loss
    as an ``ExtraColumns`` block; order is irrelevant for columns, so no roll.
    """
    return bank.buf, bank.valid


def aligned_valid(bank_q: BankState, bank_p: BankState) -> jnp.ndarray:
    """(cq,) bool — slots where bank_q row i and bank_p row i hold an aligned
    (query, positive-passage) pair. Pushed-in-lockstep banks (push_pair) are
    aligned by ring index; with unequal capacities only the common prefix can
    ever align (the pre-batch ablation has cq == 0, so no rows)."""
    cq, cp = bank_q.buf.shape[0], bank_p.buf.shape[0]
    c_align = min(cq, cp)
    aligned = jnp.zeros((cq,), dtype=bool)
    return aligned.at[:c_align].set(bank_q.valid[:c_align] & bank_p.valid[:c_align])


def ordered(bank: BankState) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(buf, valid) rolled so index 0 is the oldest entry. Only needed by
    diagnostics (similarity-mass, Appendix C) — the loss itself is
    order-independent given aligned banks."""
    cap = bank.buf.shape[0]
    perm = (bank.head + jnp.arange(cap, dtype=jnp.int32)) % cap
    return bank.buf[perm], bank.valid[perm]
