"""Shared types for the contrastive update builders."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.memory_bank import BankState


class RetrievalBatch(NamedTuple):
    """One global batch of training examples.

    query:        pytree, leaves (B, ...)   — tokenized queries
    passage_pos:  pytree, leaves (B, ...)   — the positive passage per query
    passage_hard: pytree, leaves (B, H, ...) or None — H hard negatives/query
    """

    query: Any
    passage_pos: Any
    passage_hard: Optional[Any] = None


class DualEncoder(NamedTuple):
    """Abstract dual encoder. ``params`` is expected to be a dict with keys
    'query' and 'passage' (may alias for shared towers); the encode fns take
    the full params dict."""

    init: Callable[..., Any]                       # (rng, ...) -> params
    encode_query: Callable[[Any, Any], jnp.ndarray]    # (params, batch.query) -> (B, d)
    encode_passage: Callable[[Any, Any], jnp.ndarray]  # (params, passages) -> (B, d)
    rep_dim: int


@dataclasses.dataclass(frozen=True)
class ContrastiveConfig:
    """Configuration of the contrastive update (paper Secs. 3.1-3.2).

    The update is a composition *negative source x backprop strategy*
    (core/step_program.py). Either name a registered composition with
    ``method=`` (legacy strings: 'dpr', 'grad_accum', 'grad_cache',
    'contaccum'; new: 'contcache', 'prebatch', 'prebatch_cache',
    'dpr_xdev', 'mined'/'mined_accum'/'mined_cache'), or set the axes
    explicitly:

    negatives: 'in_batch' | 'mined' | 'gathered' | 'dual_bank' |
        'passage_bank' (None -> resolved from ``method``). 'mined' marks the
        asynchronously-mined hard negatives of repro/mining: the miner's
        table is joined into every batch as extra passage_hard columns at
        assembly time (data/loader.py), so inside the program the source
        behaves exactly like 'in_batch'.
    backprop: 'direct' | 'scan' | 'rep_cache'
        (None -> resolved from ``method``). An explicitly set axis overrides
        the corresponding half of ``method``.
    accumulation_steps: K. Global batch B must be divisible by K.
    bank_size: N_memory (equal for both banks unless overridden — the paper's
        dual-bank symmetry; ``bank_size_q``/``bank_size_p`` override for the
        pre-batch-negatives ablation by *disabling* one bank. Unequal
        non-zero capacities are rejected for dual-bank sources: the rings
        stop being slot-aligned as soon as either wraps).
    reset_banks_each_update: 'w/o past encoder' ablation (Table 2).
    use_query_bank: False reproduces pre-batch negatives (w/o M_q, Table 2).
    loss_impl: 'dense' | 'fused' — how the loss's softmax statistics are
        computed (core/loss.py LossBackend). 'dense' (default) materializes
        the (M, N) logits block; 'fused' streams it through the blocked
        online-softmax Pallas kernel (gradient-exact, never materialized).
        Composes with every negatives/backprop setting.
    precision: a PrecisionPolicy or preset name ('fp32' | 'bf16' |
        'bf16_banks', core/precision.py) governing every dtype of the update:
        encoder compute copies, representations (incl. the rep_cache store),
        bank buffers and the loss-backend inputs. Softmax statistics, metric
        reductions and gradient accumulation stay in ``accum_dtype`` (fp32 in
        every preset) regardless. 'fp32' (default) is bit-identical to the
        historical all-fp32 behavior; orthogonal to negatives/backprop,
        loss_impl and shard_banks.
    bank_dtype: explicit memory-bank buffer dtype override; None (default)
        defers to ``precision`` (the normal path — set the policy, not this).
    shard_banks: shard the memory banks across the DP mesh instead of
        replicating them. Each device owns a ``bank_size / D`` contiguous
        block of ring slots (memory_bank.shard_push); the loss gathers the
        passage-bank columns over ``dp_axis`` and evaluates only the local
        query-bank rows. Identical math to replicated banks (trajectory
        parity in tests/test_distributed.py) at 1/D the per-device bank HBM.
        Requires ``dp_axis``; only meaningful under shard_map with the bank
        leaves sharded by ``memory_bank.bank_spec`` /
        ``distribution.sharding.contrastive_state_spec``.
    loss_comm: 'all_gather' | 'ring' — how sharded bank columns reach the
        loss (core/loss.py). 'all_gather' (default) gathers the full
        (N_mem, d) passage-column block before every loss eval: O(N_mem*d)
        transient memory per device, flat in D. 'ring' streams the D shards
        around the DP ring with ppermute, merging each N_mem/D chunk into the
        carried online-softmax state: exactly the same loss/gradients (fp
        summation-order tolerance) at O(N_mem*d/D) transient memory. Requires
        ``shard_banks`` (and hence ``dp_axis``) plus a bank-consuming
        negatives source; validated at program build.
    """

    method: str = "contaccum"
    negatives: Optional[str] = None
    backprop: Optional[str] = None
    temperature: float = 1.0
    accumulation_steps: int = 1
    bank_size: int = 0
    bank_size_q: Optional[int] = None
    bank_size_p: Optional[int] = None
    use_query_bank: bool = True
    reset_banks_each_update: bool = False
    grad_clip_norm: float = 2.0
    bank_dtype: Any = None
    loss_impl: str = "dense"
    # PrecisionPolicy preset name or instance (core/precision.py); 'fp32'
    # reproduces the historical all-fp32 behavior bit-for-bit.
    precision: Any = "fp32"
    # Cross-device negatives: name(s) of mesh axes to all-gather representations
    # over; None means single-device semantics.
    dp_axis: Optional[Any] = None
    # Shard the memory banks over dp_axis (capacity/D rows per device)
    # instead of replicating them; see the class docstring.
    shard_banks: bool = False
    # How sharded bank columns reach the loss: 'all_gather' materializes the
    # global block, 'ring' streams shards around the DP ring (1/D transient
    # memory); see the class docstring.
    loss_comm: str = "all_gather"

    def resolved_precision(self):
        """The PrecisionPolicy this config runs under (presets resolved)."""
        from repro.core.precision import resolve_precision

        return resolve_precision(self.precision)

    def resolved_bank_dtype(self):
        """Bank buffer dtype: explicit ``bank_dtype`` override, else the
        precision policy's ``bank_dtype``."""
        if self.bank_dtype is not None:
            return self.bank_dtype
        return self.resolved_precision().bank_dtype

    def resolved_bank_sizes(self):
        nq = self.bank_size if self.bank_size_q is None else self.bank_size_q
        np_ = self.bank_size if self.bank_size_p is None else self.bank_size_p
        if not self.use_query_bank:
            nq = 0
        return nq, np_

    def resolved_composition_names(self):
        """(negatives, backprop) names after legacy-``method`` resolution."""
        from repro.core.step_program import method_composition

        neg, bp = self.negatives, self.backprop
        if neg is None or bp is None:
            legacy = method_composition(self.method)
            neg = neg or legacy[0]
            bp = bp or legacy[1]
        return neg, bp


class ContrastiveState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any
    bank_q: BankState
    bank_p: BankState


class StepMetrics(NamedTuple):
    loss: jnp.ndarray
    accuracy: jnp.ndarray
    grad_norm: jnp.ndarray
    grad_norm_query: jnp.ndarray
    grad_norm_passage: jnp.ndarray
    grad_norm_ratio: jnp.ndarray  # ||grad_passage|| / ||grad_query|| (paper Fig. 5)
    n_negatives: jnp.ndarray      # negatives per query row actually used
    bank_fill_q: jnp.ndarray
    bank_fill_p: jnp.ndarray


def subtree_norm(grads: Any, key: str) -> jnp.ndarray:
    from repro.common.treemath import tree_global_norm

    if isinstance(grads, dict) and key in grads:
        return tree_global_norm(grads[key])
    return jnp.zeros(())


def chunk_tree(tree: Any, k: int) -> Any:
    """Reshape every leaf (B, ...) -> (K, B//K, ...)."""

    def _r(x):
        b = x.shape[0]
        assert b % k == 0, f"global batch {b} not divisible by K={k}"
        return x.reshape((k, b // k) + x.shape[1:])

    return jax.tree_util.tree_map(_r, tree)


def flatten_hard(hard: Any) -> Any:
    """(B, H, ...) -> (B*H, ...) for encoding."""

    def _f(x):
        return x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])

    return jax.tree_util.tree_map(_f, hard)
