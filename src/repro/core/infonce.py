"""InfoNCE loss with in-batch negatives for dual-encoder retrieval (paper Eq. 1/4).

This is the single shared implementation used by every update method
(DPR full-batch, GradAccum, GradCache, ContAccum) so that the methods are
comparable down to floating point.

Conventions
-----------
- ``q``: (M, d) query representations (rows of the similarity matrix).
- ``p``: (N, d) passage representations (columns). Layout when hard negatives
  are present: ``[positives (B), hard negatives (B*h), extra negatives ...]``.
- ``labels[i]``: column index of the positive passage for row i
  (defaults to ``arange(M)``, the standard in-batch diagonal).
- ``row_mask`` / ``col_mask``: validity masks. Invalid columns are excluded
  from the softmax (logit = -inf); invalid rows contribute zero loss and the
  mean is taken over valid rows only. These make the memory-bank warm-up
  phase (bank not yet full) *exact* rather than approximate.
- ``temperature``: logits = q @ p.T / temperature (paper uses tau = 1).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.precision import STATS_DTYPE

NEG_INF = -1e30


class InfoNCEOutput(NamedTuple):
    loss: jnp.ndarray          # scalar
    per_row_loss: jnp.ndarray  # (M,)
    lse: jnp.ndarray           # (M,) logsumexp over valid columns
    pos_logit: jnp.ndarray     # (M,) logit of the positive column
    accuracy: jnp.ndarray      # scalar, fraction of rows whose argmax == label
    n_valid_rows: jnp.ndarray  # scalar


def similarity_logits(
    q: jnp.ndarray,
    p: jnp.ndarray,
    *,
    temperature: float = 1.0,
    col_mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """(M, N) scaled dot-product logits with invalid columns masked to -inf."""
    logits = jnp.einsum("md,nd->mn", q, p, preferred_element_type=jnp.float32)
    logits = logits / jnp.asarray(temperature, dtype=logits.dtype)
    if col_mask is not None:
        logits = jnp.where(col_mask[None, :], logits, NEG_INF)
    return logits


def info_nce(
    q: jnp.ndarray,
    p: jnp.ndarray,
    *,
    labels: Optional[jnp.ndarray] = None,
    temperature: float = 1.0,
    row_mask: Optional[jnp.ndarray] = None,
    col_mask: Optional[jnp.ndarray] = None,
) -> InfoNCEOutput:
    """Cross-entropy of each query row against its positive column.

    All reductions happen in float32 regardless of input dtype (bf16-safe).
    """
    m = q.shape[0]
    if labels is None:
        labels = jnp.arange(m, dtype=jnp.int32)
    logits = similarity_logits(q, p, temperature=temperature, col_mask=col_mask)
    logits = logits.astype(STATS_DTYPE)
    lse = jax.nn.logsumexp(logits, axis=-1)
    # mode="clip": masked-out rows may carry out-of-range labels (e.g. bank
    # rows with no aligned passage); the default fill mode would yield NaN
    # which then poisons the masked mean via 0 * NaN.
    pos = jnp.take_along_axis(
        logits, labels[:, None].astype(jnp.int32), axis=-1, mode="clip"
    )[:, 0]
    per_row = lse - pos
    if row_mask is None:
        row_mask = jnp.ones((m,), dtype=bool)
    row_mask_f = row_mask.astype(STATS_DTYPE)
    n_valid = jnp.maximum(row_mask_f.sum(), 1.0)
    loss = jnp.sum(per_row * row_mask_f) / n_valid
    preds = jnp.argmax(logits, axis=-1)
    acc = jnp.sum((preds == labels) * row_mask_f) / n_valid
    return InfoNCEOutput(
        loss=loss,
        per_row_loss=per_row,
        lse=lse,
        pos_logit=pos,
        accuracy=acc,
        n_valid_rows=n_valid,
    )


def in_batch_loss(
    q: jnp.ndarray,
    p_pos: jnp.ndarray,
    p_hard: Optional[jnp.ndarray] = None,
    *,
    temperature: float = 1.0,
) -> InfoNCEOutput:
    """DPR-style loss: positives on the diagonal, hard negatives appended as columns.

    q: (B, d); p_pos: (B, d); p_hard: (B*h, d) or None.
    """
    cols = p_pos if p_hard is None else jnp.concatenate([p_pos, p_hard], axis=0)
    return info_nce(q, cols, temperature=temperature)


def extended_loss(
    q_local: jnp.ndarray,
    p_pos: jnp.ndarray,
    p_hard: Optional[jnp.ndarray],
    bank_q_buf: Optional[jnp.ndarray],
    bank_q_valid: Optional[jnp.ndarray],
    bank_p_buf: Optional[jnp.ndarray],
    bank_p_valid: Optional[jnp.ndarray],
    *,
    temperature: float = 1.0,
) -> InfoNCEOutput:
    """ContAccum extended similarity matrix (paper Eq. 5-7).

    Rows    = [local queries (B)] ++ [bank queries (Cq)]
    Columns = [local positives (B)] ++ [local hard negatives (B*h)] ++ [bank passages (Cp)]

    Bank entries carry ``stop_gradient`` *upstream of this function* (the bank
    buffers are leaves of the train state, not traced activations), matching
    the paper's sg(M_q), sg(M_p). Bank query row i's positive is bank passage
    i: both banks are pushed in lockstep so ring positions align. Rows/cols of
    invalid (not yet filled) bank slots are masked out exactly.

    When the two banks have different capacities (e.g. passage-only bank =
    pre-batch negatives), the bank-query rows whose aligned passage column does
    not exist are masked out, reproducing the asymmetric gradient flow the
    paper analyzes in Sec. 3.3.
    """
    b = q_local.shape[0]
    row_parts = [q_local]
    row_mask_parts = [jnp.ones((b,), dtype=bool)]
    col_parts = [p_pos]
    n_pos = p_pos.shape[0]
    col_mask_parts = [jnp.ones((n_pos,), dtype=bool)]
    if p_hard is not None and p_hard.shape[0] > 0:
        col_parts.append(p_hard)
        col_mask_parts.append(jnp.ones((p_hard.shape[0],), dtype=bool))
    n_hard = 0 if p_hard is None else p_hard.shape[0]

    cq = 0 if bank_q_buf is None else bank_q_buf.shape[0]
    cp = 0 if bank_p_buf is None else bank_p_buf.shape[0]

    if cp > 0:
        col_parts.append(bank_p_buf)
        col_mask_parts.append(bank_p_valid)
    if cq > 0:
        row_parts.append(bank_q_buf)
        if cp > 0:
            c_align = min(cq, cp)
            # bank query i is valid as a row only if its aligned passage exists
            aligned = jnp.zeros((cq,), dtype=bool)
            aligned = aligned.at[:c_align].set(
                bank_q_valid[:c_align] & bank_p_valid[:c_align]
            )
            row_mask_parts.append(aligned)
        else:
            # no passage bank: bank-query rows have no positive -> masked out.
            # (They then contribute nothing; this degenerate setting is only
            # reachable through ablation flags.)
            row_mask_parts.append(jnp.zeros((cq,), dtype=bool))

    q_all = jnp.concatenate(row_parts, axis=0)
    p_all = jnp.concatenate(col_parts, axis=0)
    row_mask = jnp.concatenate(row_mask_parts, axis=0)
    col_mask = jnp.concatenate(col_mask_parts, axis=0)

    labels = jnp.concatenate(
        [
            jnp.arange(b, dtype=jnp.int32),
            # bank query i -> bank passage column i (after pos+hard columns)
            n_pos + n_hard + jnp.arange(cq, dtype=jnp.int32) % max(cp, 1)
            if cq > 0
            else jnp.zeros((0,), dtype=jnp.int32),
        ],
        axis=0,
    )
    return info_nce(
        q_all,
        p_all,
        labels=labels,
        temperature=temperature,
        row_mask=row_mask,
        col_mask=col_mask,
    )
