"""Distribution context for the contrastive update builders.

``DistCtx`` abstracts over single-device and shard_map execution so that the
four methods (DPR / GradAccum / GradCache / ContAccum) are written once.

Under ``shard_map`` the batch is sharded over the data-parallel axes; each
device encodes its local shard, all-gathers the representations (cross-device
in-batch negatives — the pod-scale reading of the paper's "total batch") and
computes the loss over its *own* rows only. Gradients flow through the
all_gather (transpose = psum_scatter sums the cotangents contributed by every
device's loss), after which a single psum over the DP axes yields exactly the
gradient of the global-batch loss. This is validated against single-device
execution in tests/test_distributed.py.
"""

from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

AxisNames = Union[str, Tuple[str, ...]]


def get_shard_map():
    """(shard_map, version_kwargs) across jax releases: >= 0.5 exposes
    ``jax.shard_map`` with ``check_vma``; older releases keep it in
    ``jax.experimental.shard_map`` with ``check_rep``. The kwargs disable
    replication checking (the update's psum'ed outputs are replicated by
    construction, which the static checker cannot always prove)."""
    import inspect

    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    if "check_vma" in inspect.signature(sm).parameters:
        return sm, {"check_vma": False}
    return sm, {"check_rep": False}


class DistCtx:
    """axis=None -> single-device semantics (gather = identity, psum = identity)."""

    def __init__(self, axis: Optional[AxisNames] = None):
        if isinstance(axis, str):
            axis = (axis,)
        self.axis: Optional[Tuple[str, ...]] = tuple(axis) if axis else None

    @property
    def is_distributed(self) -> bool:
        return self.axis is not None

    def device_count(self):
        if not self.axis:
            return 1
        n = 1
        for a in self.axis:
            n = n * jax.lax.psum(1, a)
        return n

    def shard_index(self):
        """Flat index of this device along the combined DP axes (major-to-minor
        in the order given, matching all_gather's concatenation order)."""
        if not self.axis:
            return 0
        idx = 0
        for a in self.axis:
            idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
        return idx

    def gather(self, x: jnp.ndarray) -> jnp.ndarray:
        """Concatenate shards along axis 0 (differentiable)."""
        if not self.axis:
            return x
        return jax.lax.all_gather(x, self.axis, axis=0, tiled=True)

    def ring_perm(self, shift: int = 1):
        """Static ppermute pairs rotating the combined DP ring by ``shift``:
        flat device i (in ``shard_index`` order) sends to (i + shift) mod D —
        one complete cycle covering every device, so no shard's contribution
        is ever dropped (reprolint RPL002 enforces this for literal tables).
        ``device_count``/``psum(1, axis)`` are static under shard_map, so the
        table is a compile-time constant."""
        d = self.device_count()
        return [(i, (i + shift) % d) for i in range(d)]

    def ring_rotate(self, x, shift: int = 1):
        """Rotate every leaf of ``x`` one hop around the flattened DP ring
        (device i receives device (i - shift) mod D's value). Differentiable:
        ppermute's transpose is the inverse rotation, so cotangents written
        against a neighbor's shard ride the ring *back* to the owning device
        and sum there — the streaming-loss backward pass needs no extra
        collective. Identity in single-device mode."""
        if not self.axis:
            return x
        perm = self.ring_perm(shift)
        return jax.tree_util.tree_map(
            lambda t: jax.lax.ppermute(t, self.axis, perm=perm), x
        )

    def psum(self, x):
        if not self.axis:
            return x
        return jax.lax.psum(x, self.axis)

    def psum_tree(self, tree):
        if not self.axis:
            return tree
        return jax.tree_util.tree_map(lambda t: jax.lax.psum(t, self.axis), tree)
