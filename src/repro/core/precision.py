"""PrecisionPolicy: one object owning every dtype decision of the stack.

The paper's premise is training dense retrievers *under a memory constraint*,
and mixed precision is the memory-scaling axis GradCache (Gao et al., 2021)
and Inf-CL ("Breaking the Memory Barrier", Cheng et al., 2024) treat as table
stakes. Before this module the reproduction's dtypes were scattered implicit
fp32 assumptions (encoder activations, bank buffers, optimizer moments, loss
statistics); now every layer consumes a single ``PrecisionPolicy``:

  * ``param_dtype``   — dtype of the *stored* parameters (the train state).
    All shipped presets keep this fp32: the stored params are the AdamW
    master weights, and the encoders cast them to ``compute_dtype`` at
    application (bf16 "compute copies" are transient, never stored). True
    low-precision param storage is supported by
    ``optim.adamw(keep_master_params=True)``, which then carries the fp32
    masters inside the optimizer state instead.
  * ``compute_dtype`` — encoder activations, representations (including the
    rep-cache store of the ``rep_cache`` backprop strategy) and the q/p/bank
    inputs of both loss backends.
  * ``bank_dtype``    — the FIFO memory-bank ring buffers
    (``core/memory_bank.py``); halves persistent bank HBM again on top of
    bank sharding (bank bytes / (2·D)).
  * ``accum_dtype``   — softmax statistics (logits, lse, per-row losses),
    VJP accumulation inside the fused Pallas kernel, metric reductions and
    gradient accumulation arithmetic. Always fp32 in the shipped presets:
    low-precision *statistics* change the optimization trajectory, while
    low-precision *inputs* only perturb it within rounding tolerance
    (tests/test_precision.py pins both properties).

Presets::

    fp32        params fp32 | compute fp32 | banks fp32 | accum fp32
    bf16        params fp32 | compute bf16 | banks fp32 | accum fp32
    bf16_banks  params fp32 | compute bf16 | banks bf16 | accum fp32

``fp32`` is bit-identical to the historical behavior (every cast is an
identity). Select with ``ContrastiveConfig(precision=...)`` (a preset name or
a ``PrecisionPolicy`` instance), ``--precision`` on both train drivers, or a
shape cell's ``"precision"`` param.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Union

import jax
import jax.numpy as jnp


#: Named contract dtypes. These are the *non-negotiable* fp32 anchors of the
#: stack — unlike the policy fields above they never vary per preset, and
#: call sites reference them by contract name so a reader (and reprolint's
#: RPL001) can tell a deliberate fp32 pin from a forgotten policy bypass.
#:
#: STATS_DTYPE — every statistic that feeds logging or control decisions
#:   (loss, accuracy, bank fill, retrieval recall) is cast here *before* the
#:   reduction; low-precision statistics change the trajectory, not just
#:   perturb it (tests/test_precision.py).
#: SCORE_DTYPE — retrieval similarity scores and top-k merge buffers; a bf16
#:   score merge reorders near-ties across shards and breaks exact/sharded
#:   search equivalence (tests/test_retriever.py).
#: MASTER_DTYPE — AdamW master weights and moments (optim/ keeps its own
#:   literal copy: importing this module from optim/ would cycle through
#:   repro.core.__init__ -> step_program -> optim).
STATS_DTYPE = jnp.float32
SCORE_DTYPE = jnp.float32
MASTER_DTYPE = jnp.float32


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Dtype assignments for one training run (see module docstring)."""

    name: str = "fp32"
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    bank_dtype: Any = jnp.float32
    accum_dtype: Any = jnp.float32

    def cast_compute(self, x):
        """Cast an array (or None) to the compute dtype; identity under fp32."""
        if x is None:
            return None
        return x.astype(self.compute_dtype)


PRECISION_PRESETS = {
    "fp32": PrecisionPolicy(name="fp32"),
    "bf16": PrecisionPolicy(name="bf16", compute_dtype=jnp.bfloat16),
    "bf16_banks": PrecisionPolicy(
        name="bf16_banks", compute_dtype=jnp.bfloat16, bank_dtype=jnp.bfloat16
    ),
}

_FP32 = PRECISION_PRESETS["fp32"]


def resolve_precision(
    spec: Union[None, str, PrecisionPolicy] = None,
) -> PrecisionPolicy:
    """None -> fp32; a preset name -> the registered policy; an instance ->
    as is. Raises ValueError for unknown names (surfaced at program build)."""
    if spec is None:
        return _FP32
    if isinstance(spec, str):
        if spec not in PRECISION_PRESETS:
            raise ValueError(
                f"unknown precision {spec!r}; one of {sorted(PRECISION_PRESETS)}"
            )
        return PRECISION_PRESETS[spec]
    return spec


def apply_compute_dtype(encoder, policy: Union[str, PrecisionPolicy]):
    """Wrap a DualEncoder so params are cast to ``compute_dtype`` at
    application and the emitted representations are in ``compute_dtype``.

    The BERT towers honor a policy natively (``BertConfig.with_precision``);
    this generic wrapper gives every other encoder — including the tiny MLP
    test towers — the same mixed-precision semantics: stored params stay in
    ``param_dtype`` (fp32 masters), transient compute copies are created per
    application, float inputs are cast alongside. Identity under fp32.
    """
    from repro.core.types import DualEncoder

    policy = resolve_precision(policy)
    ct = policy.compute_dtype

    def _cast_tree(tree):
        return jax.tree_util.tree_map(
            lambda a: a.astype(ct) if jnp.issubdtype(a.dtype, jnp.floating) else a,
            tree,
        )

    def encode_query(params, batch):
        return encoder.encode_query(_cast_tree(params), _cast_tree(batch)).astype(ct)

    def encode_passage(params, batch):
        return encoder.encode_passage(_cast_tree(params), _cast_tree(batch)).astype(ct)

    def init(rng, *a, **kw):
        return jax.tree_util.tree_map(
            lambda p: p.astype(policy.param_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating)
            else p,
            encoder.init(rng, *a, **kw),
        )

    return DualEncoder(
        init=init,
        encode_query=encode_query,
        encode_passage=encode_passage,
        rep_dim=encoder.rep_dim,
    )


def bank_bytes_per_device(
    capacity_q: int,
    capacity_p: int,
    rep_dim: int,
    policy: Union[None, str, PrecisionPolicy] = None,
    *,
    shards: int = 1,
) -> int:
    """Persistent dual-bank buffer bytes per device: the memory axis this
    policy exists to cut. ``shards`` is the DP shard count under
    ``cfg.shard_banks`` (1 = replicated). Counts the representation buffers
    only — the valid/age sidecars are capacity-proportional but d-free."""
    policy = resolve_precision(policy)
    itemsize = jnp.dtype(policy.bank_dtype).itemsize
    return ((capacity_q + capacity_p) * rep_dim * itemsize) // max(shards, 1)
