"""Core contribution of the paper: memory-constrained contrastive training
for dual-encoder retrieval (ContAccum) plus the baselines it is compared to.
"""

from repro.core.infonce import info_nce, in_batch_loss, extended_loss, similarity_logits, InfoNCEOutput
from repro.core.memory_bank import BankState, init_bank, push, push_pair, clear, n_valid, ordered
from repro.core.loss import contrastive_step_loss, LossAux
from repro.core.dist import DistCtx
from repro.core.types import (
    ContrastiveConfig,
    ContrastiveState,
    DualEncoder,
    RetrievalBatch,
    StepMetrics,
    chunk_tree,
    flatten_hard,
)
from repro.core.methods import (
    init_state,
    make_update_fn,
    make_dpr_update,
    make_grad_accum_update,
    make_grad_cache_update,
    make_contaccum_update,
)

__all__ = [
    "info_nce", "in_batch_loss", "extended_loss", "similarity_logits", "InfoNCEOutput",
    "BankState", "init_bank", "push", "push_pair", "clear", "n_valid", "ordered",
    "contrastive_step_loss", "LossAux", "DistCtx",
    "ContrastiveConfig", "ContrastiveState", "DualEncoder", "RetrievalBatch",
    "StepMetrics", "chunk_tree", "flatten_hard",
    "init_state", "make_update_fn", "make_dpr_update", "make_grad_accum_update",
    "make_grad_cache_update", "make_contaccum_update",
]
