"""Core contribution of the paper: memory-constrained contrastive training
for dual-encoder retrieval (ContAccum) plus the baselines it is compared to.
"""

from repro.core.infonce import info_nce, in_batch_loss, extended_loss, similarity_logits, InfoNCEOutput
from repro.core.memory_bank import (
    BankState, init_bank, push, push_pair, clear, n_valid, ordered,
    aligned_valid, capacity, columns_view, shard_push, shard_push_pair,
    bank_spec,
)
from repro.core.loss import (
    contrastive_loss, contrastive_step_loss, LossAux,
    ExtraColumns, ExtraRows, bank_extra_columns, bank_extra_rows,
    sharded_bank_extra_columns, sharded_bank_extra_rows,
    LossBackend, DenseLossBackend, FusedLossBackend, LOSS_BACKENDS,
    resolve_loss_backend,
)
from repro.core.dist import DistCtx, get_shard_map
from repro.core.precision import (
    PRECISION_PRESETS,
    PrecisionPolicy,
    apply_compute_dtype,
    bank_bytes_per_device,
    resolve_precision,
)
from repro.core.step_program import (
    COMPOSITIONS,
    SOURCES,
    STRATEGIES,
    BackpropStrategy,
    NegativeSource,
    StepProgram,
    available_methods,
    build_step_program,
    method_composition,
    method_needs_mesh,
    method_uses_banks,
    resolve_composition,
)
from repro.core.types import (
    ContrastiveConfig,
    ContrastiveState,
    DualEncoder,
    RetrievalBatch,
    StepMetrics,
    chunk_tree,
    flatten_hard,
)
from repro.core.methods import (
    init_state,
    make_update_fn,
    make_dpr_update,
    make_grad_accum_update,
    make_grad_cache_update,
    make_contaccum_update,
)

__all__ = [
    "info_nce", "in_batch_loss", "extended_loss", "similarity_logits", "InfoNCEOutput",
    "BankState", "init_bank", "push", "push_pair", "clear", "n_valid", "ordered",
    "aligned_valid", "capacity", "columns_view", "shard_push", "shard_push_pair",
    "bank_spec",
    "contrastive_loss", "contrastive_step_loss", "LossAux",
    "ExtraColumns", "ExtraRows", "bank_extra_columns", "bank_extra_rows",
    "sharded_bank_extra_columns", "sharded_bank_extra_rows",
    "LossBackend", "DenseLossBackend", "FusedLossBackend", "LOSS_BACKENDS",
    "resolve_loss_backend",
    "DistCtx", "get_shard_map",
    "PRECISION_PRESETS", "PrecisionPolicy", "apply_compute_dtype",
    "bank_bytes_per_device", "resolve_precision",
    "ContrastiveConfig", "ContrastiveState", "DualEncoder", "RetrievalBatch",
    "StepMetrics", "chunk_tree", "flatten_hard",
    "COMPOSITIONS", "SOURCES", "STRATEGIES",
    "BackpropStrategy", "NegativeSource", "StepProgram",
    "available_methods", "build_step_program", "method_composition",
    "method_needs_mesh", "method_uses_banks", "resolve_composition",
    "init_state", "make_update_fn", "make_dpr_update", "make_grad_accum_update",
    "make_grad_cache_update", "make_contaccum_update",
]
