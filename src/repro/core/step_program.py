"""Composable contrastive update construction: the `StepProgram` API.

The paper's four methods (and the useful configurations beyond them) are
points in a 2-D design space:

  * **where negatives come from** — a ``NegativeSource``: in-batch only,
    dual FIFO memory banks (ContAccum), a passage-only bank (pre-batch
    negatives), or cross-device-gathered in-batch negatives. A source owns
    its slice of the similarity matrix (extra columns / rows + masks, built
    on core/loss.py's ExtraColumns/ExtraRows) and the bank state carried
    across accumulation chunks.

  * **how the backward pass is scheduled** — a ``BackpropStrategy``: direct
    (one forward/backward over the whole batch), scan-accumulate (K chunks,
    loss restricted to each chunk — paper Eq. 4), or rep-cache VJP
    (GradCache's decomposition, Gao et al. 2021: representation-only
    forward, loss differentiated w.r.t. the representations, per-chunk VJPs
    through the encoders — full-batch gradients at chunked memory).

``build_step_program(encoder, tx, cfg)`` combines one of each into an
``update(state, batch) -> (state, StepMetrics)`` that also owns metric
assembly and bank pushes; all programs are pure and jit/shard_map
compatible. The legacy ``method=`` strings are a thin registry over
compositions (COMPOSITIONS):

    dpr            = direct          x in-batch
    grad_accum     = scan-accumulate x in-batch
    grad_cache     = rep-cache VJP   x in-batch
    contaccum      = scan-accumulate x dual-bank     (the paper's method)
    contcache      = rep-cache VJP   x dual-bank     (new: exact full-batch
                     backprop *and* bank-extended negatives)
    prebatch       = scan-accumulate x passage-bank  (pre-batch ablation)
    prebatch_cache = rep-cache VJP   x passage-bank  (new)
    dpr_xdev       = direct          x gathered      (cross-device in-batch)
    mined          = direct          x mined         (ANCE-style mined
    mined_accum    = scan-accumulate x mined          negatives, injected as
    mined_cache    = rep-cache VJP   x mined          passage_hard columns by
                     repro/mining's asynchronous refresh pipeline)

The four legacy compositions are gradient-exact against the original
monolithic implementations (tests/test_step_program.py).

Orthogonal to both axes, ``cfg.loss_impl`` picks the **LossBackend**
(core/loss.py): 'dense' (einsum logits block, default) or 'fused' (the
blocked online-softmax Pallas kernel) — every source x strategy composition
runs on either backend, gradient-exact to fp32 tolerance
(tests/test_fused_infonce.py).

Orthogonal to everything above, ``cfg.precision`` selects the
**PrecisionPolicy** (core/precision.py): presets ``fp32`` (default,
bit-identical to the historical behavior), ``bf16`` (bf16 encoder compute +
representations, fp32 masters/banks/statistics) and ``bf16_banks`` (bf16
compute *and* bf16 bank buffers). The policy is threaded through every
source x strategy composition: the loss casts representations and bank
blocks to ``compute_dtype`` in one place, the rep_cache representation store
is kept in ``compute_dtype``, bank rings are allocated in ``bank_dtype``,
and softmax statistics / metric reductions / gradient accumulation stay in
``accum_dtype`` (fp32 in every preset). bf16 trajectories track the fp32
reference within documented tolerance for the full matrix
(tests/test_precision.py).

Also orthogonal, ``cfg.shard_banks`` picks the bank **distribution mode**
under shard_map: replicated (default — every device carries the full rings
and pushes the gathered global rows) or sharded (each device owns a
``capacity/D`` ring-slot block; pushes write only local rows, the loss
gathers the passage-bank columns over ``cfg.dp_axis`` and evaluates only the
local query-bank rows). Both modes are trajectory-identical to the
single-device replicated run (tests/test_distributed.py); sharded mode cuts
per-device bank HBM and extra-row compute by 1/D.

On top of sharded banks, ``cfg.loss_comm`` picks how the shard-local passage
columns reach each loss evaluation: ``'all_gather'`` (default) materializes
the global (N_mem, d) block on every device, ``'ring'`` streams the D shards
around the DP ring with ppermute and merges each N_mem/D chunk into the
backend's carried online-softmax state (core/loss.py ``_ring_row_stats``) —
the same loss and gradients (fp summation-order tolerance,
tests/test_ring_parity.py) at O(N_mem*d/D) transient memory per eval.
``'ring'`` requires a bank-consuming source with ``shard_banks=True``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp

from repro.common.treemath import tree_add, tree_scale, tree_zeros_like, tree_global_norm
from repro.core.dist import DistCtx
from repro.core.loss import (
    LossAux,
    LossBackend,
    bank_extra_columns,
    bank_extra_rows,
    contrastive_loss,
    resolve_loss_backend,
    sharded_bank_extra_columns,
    sharded_bank_extra_rows,
)
from repro.core.memory_bank import (
    BankState,
    clear,
    init_bank,
    push,
    push_pair,
    shard_push,
    shard_push_pair,
)
from repro.core.precision import STATS_DTYPE, resolve_precision
from repro.core.types import (
    ContrastiveConfig,
    ContrastiveState,
    DualEncoder,
    RetrievalBatch,
    StepMetrics,
    chunk_tree,
    flatten_hard,
    subtree_norm,
)
from repro.optim.adamw import GradientTransformation, apply_updates

# Bank state threaded across chunks by every program: (bank_q, bank_p).
# Sources without banks carry 0-capacity rings so the scan carry keeps a
# uniform pytree structure.
Carry = Tuple[BankState, BankState]

LOSS_COMMS = ("all_gather", "ring")


def _validate_loss_comm(cfg: ContrastiveConfig, *, uses_banks: bool) -> None:
    """Shared loss_comm checks, surfaced at program build."""
    if cfg.loss_comm not in LOSS_COMMS:
        raise ValueError(
            f"unknown loss_comm {cfg.loss_comm!r}; one of {sorted(LOSS_COMMS)}"
        )
    if cfg.loss_comm == "ring":
        if not uses_banks:
            raise ValueError(
                "loss_comm='ring' streams sharded bank columns around the DP "
                "ring, but this negatives source has no bank columns — use a "
                "bank-consuming source (dual_bank / passage_bank) or leave "
                "loss_comm='all_gather'"
            )
        if not cfg.shard_banks:
            raise ValueError(
                "loss_comm='ring' needs shard_banks=True (each device must "
                "own one N_mem/D shard to stream); replicated banks already "
                "hold the full column block locally"
            )


# --------------------------------------------------------------------------
# NegativeSource protocol + implementations
# --------------------------------------------------------------------------
class NegativeSource(Protocol):
    """Where the negatives of one loss evaluation come from."""

    name: str
    uses_banks: bool   # does this source read/write the FIFO banks?
    needs_mesh: bool   # does this source require cfg.dp_axis (a mesh)?

    def bank_sizes(self, cfg: ContrastiveConfig) -> Tuple[int, int]:
        """(capacity_q, capacity_p) this source wants allocated in state."""
        ...

    def validate(self, cfg: ContrastiveConfig) -> None:
        """Raise ValueError for configs this source cannot serve."""
        ...

    def begin(self, state: ContrastiveState, cfg: ContrastiveConfig) -> Carry:
        """Bank carry at the start of one update."""
        ...

    def loss(
        self,
        q: jnp.ndarray,
        pp: jnp.ndarray,
        ph: Optional[jnp.ndarray],
        carry: Carry,
        *,
        cfg: ContrastiveConfig,
        ctx: DistCtx,
        backend: Optional[LossBackend] = None,
    ) -> Tuple[jnp.ndarray, LossAux]:
        """One loss evaluation with this source's columns/rows/masks,
        computed by ``backend`` (None -> dense). ``cfg`` carries the
        temperature and the bank distribution mode (``shard_banks``)."""
        ...

    def push(
        self,
        carry: Carry,
        aux: LossAux,
        step: jnp.ndarray,
        *,
        cfg: ContrastiveConfig,
        ctx: DistCtx,
    ) -> Carry:
        """Update carried state after one loss evaluation (bank pushes).
        Shard-aware: with ``cfg.shard_banks`` each device writes only its own
        ring-slot block of the gathered global rows."""
        ...


class InBatchNegatives:
    """Plain in-batch negatives (DPR / GradAccum / GradCache): no extras.

    Banks in state are allocated per cfg for layout compatibility but never
    read or written."""

    name = "in_batch"
    uses_banks = False
    needs_mesh = False

    def bank_sizes(self, cfg):
        return cfg.resolved_bank_sizes()

    def validate(self, cfg):
        _validate_loss_comm(cfg, uses_banks=False)

    def begin(self, state, cfg):
        return (state.bank_q, state.bank_p)

    def loss(self, q, pp, ph, carry, *, cfg, ctx, backend=None):
        return contrastive_loss(
            q, pp, ph, temperature=cfg.temperature, ctx=ctx, backend=backend,
            precision=cfg.resolved_precision(),
        )

    def push(self, carry, aux, step, *, cfg, ctx):
        return carry


class MinedNegatives(InBatchNegatives):
    """ANCE-style globally-mined hard negatives (``negatives="mined"``).

    The asynchronous miner (repro/mining) publishes per-query negative ids;
    batch assembly (data/loader.py ``MinedNegativeInjector``) joins them in
    as extra ``passage_hard`` columns *before* the batch reaches the
    program. Inside the update the mined passages are therefore ordinary
    hard-negative columns — the loss math is identical to in-batch, which
    is exactly why this source composes with every BackpropStrategy
    unchanged, and why bank sources pick mined columns up for free
    (contaccum x mined = ``method='contaccum'`` + the injector: the mined
    columns ride ``passage_hard`` while the banks keep extending the
    matrix). The class exists to state the intent in the registry and to
    give the composition a first-class name."""

    name = "mined"


class GatheredInBatch(InBatchNegatives):
    """Cross-device in-batch negatives: identical math to ``in_batch`` (the
    loss all-gathers columns whenever cfg.dp_axis names mesh axes) but states
    the intent and refuses to build without a DP axis."""

    name = "gathered"
    needs_mesh = True

    def validate(self, cfg):
        super().validate(cfg)
        if cfg.dp_axis is None:
            raise ValueError(
                "negatives='gathered' needs cfg.dp_axis naming the mesh axes "
                "to all-gather representations over"
            )


class DualBankNegatives:
    """The paper's dual FIFO memory banks (Sec. 3.2): the passage bank
    extends the columns, the query bank adds extra rows labeled with their
    lockstep-aligned bank positives; both are pushed after every loss
    evaluation."""

    name = "dual_bank"
    uses_banks = True
    needs_mesh = False

    def bank_sizes(self, cfg):
        return cfg.resolved_bank_sizes()

    def validate(self, cfg):
        # bank-less dual-bank degrades exactly to in-batch; allowed (the
        # warm-up / reduction identities rely on it)
        nq, np_ = self.bank_sizes(cfg)
        if nq and np_ and nq != np_:
            raise ValueError(
                f"dual banks need equal non-zero capacities to stay "
                f"ring-aligned (got bank_size_q={nq}, bank_size_p={np_}): "
                f"heads advance mod different capacities, so after a wrap "
                f"row i of M_q no longer holds the query whose positive is "
                f"row i of M_p. Use bank_size=, or disable one bank "
                f"(capacity 0) for the pre-batch ablation."
            )
        if cfg.shard_banks and cfg.dp_axis is None:
            raise ValueError(
                "shard_banks=True needs cfg.dp_axis naming the mesh axes the "
                "bank rows are sharded over (single-device banks are already "
                "'sharded' into one shard — just leave shard_banks off)"
            )
        _validate_loss_comm(cfg, uses_banks=True)

    def begin(self, state, cfg):
        if cfg.reset_banks_each_update:
            return (clear(state.bank_q), clear(state.bank_p))
        return (state.bank_q, state.bank_p)

    def _sharded(self, cfg, ctx) -> bool:
        return cfg.shard_banks and ctx.is_distributed

    def loss(self, q, pp, ph, carry, *, cfg, ctx, backend=None):
        bank_q, bank_p = carry
        if self._sharded(cfg, ctx):
            # shard-local banks: columns reach the loss either gathered to
            # the global block or ring-streamed shard by shard (loss_comm);
            # rows are evaluated locally either way (each device owns a
            # distinct 1/D partition)
            extra_cols = sharded_bank_extra_columns(bank_p, ctx, cfg.loss_comm)
            extra_rows = sharded_bank_extra_rows(bank_q, bank_p, ctx)
        else:
            extra_cols = bank_extra_columns(bank_p)
            extra_rows = bank_extra_rows(bank_q, bank_p)
        return contrastive_loss(
            q,
            pp,
            ph,
            extra_cols=extra_cols,
            extra_rows=extra_rows,
            temperature=cfg.temperature,
            ctx=ctx,
            backend=backend,
            precision=cfg.resolved_precision(),
        )

    def push(self, carry, aux, step, *, cfg, ctx):
        bank_q, bank_p = carry
        if self._sharded(cfg, ctx):
            # each device writes only its own ring-slot block of the global
            # rows; the replicated global head advances identically everywhere
            return shard_push_pair(
                bank_q, bank_p, aux.q_global, aux.p_global, step,
                shard_index=ctx.shard_index(), num_shards=ctx.device_count(),
            )
        # Enqueue the *global* representations (identical on all devices in
        # distributed mode -> banks stay replicated).
        return push_pair(bank_q, bank_p, aux.q_global, aux.p_global, step)


class PassageBankNegatives(DualBankNegatives):
    """Passage-only bank — the 'pre-batch negatives' ablation (w/o M_q,
    Table 2): columns are extended, no extra rows, only passages pushed."""

    name = "passage_bank"

    def bank_sizes(self, cfg):
        # query bank disabled; the passage bank is the whole source
        _, np_ = cfg.resolved_bank_sizes()
        return 0, np_

    def loss(self, q, pp, ph, carry, *, cfg, ctx, backend=None):
        _, bank_p = carry
        extra_cols = (
            sharded_bank_extra_columns(bank_p, ctx, cfg.loss_comm)
            if self._sharded(cfg, ctx)
            else bank_extra_columns(bank_p)
        )
        return contrastive_loss(
            q,
            pp,
            ph,
            extra_cols=extra_cols,
            temperature=cfg.temperature,
            ctx=ctx,
            backend=backend,
            precision=cfg.resolved_precision(),
        )

    def push(self, carry, aux, step, *, cfg, ctx):
        bank_q, bank_p = carry
        if self._sharded(cfg, ctx):
            return bank_q, shard_push(
                bank_p, aux.p_global, step,
                shard_index=ctx.shard_index(), num_shards=ctx.device_count(),
            )
        return bank_q, push(bank_p, aux.p_global, step)


# --------------------------------------------------------------------------
# BackpropStrategy protocol + implementations
# --------------------------------------------------------------------------
class BackpropStrategy(Protocol):
    """How encoder gradients are obtained from the source's loss."""

    name: str

    def validate(self, cfg: ContrastiveConfig) -> None:
        ...

    def compute(
        self,
        encoder: DualEncoder,
        params: Any,
        batch: RetrievalBatch,
        source: NegativeSource,
        carry: Carry,
        step: jnp.ndarray,
        cfg: ContrastiveConfig,
        ctx: DistCtx,
    ) -> Tuple[Any, LossAux, Carry]:
        """Returns (psum'ed grads, reduced aux, final carry)."""
        ...


def _encode_chunk(encoder: DualEncoder, params, chunk: RetrievalBatch):
    q = encoder.encode_query(params, chunk.query)
    pp = encoder.encode_passage(params, chunk.passage_pos)
    ph = None
    if chunk.passage_hard is not None:
        ph = encoder.encode_passage(params, flatten_hard(chunk.passage_hard))
    return q, pp, ph


def _chunk_batch(batch: RetrievalBatch, k: int) -> RetrievalBatch:
    return RetrievalBatch(
        query=chunk_tree(batch.query, k),
        passage_pos=chunk_tree(batch.passage_pos, k),
        passage_hard=None
        if batch.passage_hard is None
        else chunk_tree(batch.passage_hard, k),
    )


def _reduce_scanned_aux(auxs: LossAux) -> LossAux:
    """Reduce per-chunk aux to update-level metrics. Each chunk's loss /
    accuracy is already a mean over that chunk's rows, and the row counts
    differ while the banks warm up (later chunks see more valid extra rows) —
    so the chunks are recombined weighted by ``n_rows``, giving the exact
    mean over every row of the update rather than a mean of chunk means."""
    n = auxs.n_rows
    n_total = jnp.maximum(n.sum(), 1.0)
    return LossAux(
        loss=(auxs.loss * n).sum() / n_total,
        accuracy=(auxs.accuracy * n).sum() / n_total,
        n_rows=n.sum(),
        n_negatives=auxs.n_negatives.mean(),
        q_global=auxs.q_global,
        p_global=auxs.p_global,
    )


class DirectBackprop:
    """One forward/backward over the whole batch (full activation memory)."""

    name = "direct"

    def validate(self, cfg):
        pass

    def compute(self, encoder, params, batch, source, carry, step, cfg, ctx):
        backend = resolve_loss_backend(cfg.loss_impl)

        def loss_fn(p):
            q, pp, ph = _encode_chunk(encoder, p, batch)
            return source.loss(q, pp, ph, carry, cfg=cfg, ctx=ctx, backend=backend)

        (_, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads = ctx.psum_tree(grads)
        carry = source.push(carry, aux, step, cfg=cfg, ctx=ctx)
        return grads, aux, carry


class ScanAccumulate:
    """K chunks under jax.lax.scan, loss restricted to each chunk (paper
    Eq. 4); the source's carry (banks) threads through the scan, so each
    chunk sees every previous chunk's pushes."""

    name = "scan"

    def validate(self, cfg):
        if cfg.accumulation_steps < 1:
            raise ValueError("accumulation_steps must be >= 1")

    def compute(self, encoder, params, batch, source, carry, step, cfg, ctx):
        k = cfg.accumulation_steps
        chunks = _chunk_batch(batch, k)
        backend = resolve_loss_backend(cfg.loss_impl)

        def body(c, chunk):
            grads_acc, carry_ = c

            def loss_fn(p):
                q, pp, ph = _encode_chunk(encoder, p, chunk)
                return source.loss(
                    q, pp, ph, carry_, cfg=cfg, ctx=ctx, backend=backend
                )

            (_, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
            carry_ = source.push(carry_, aux, step, cfg=cfg, ctx=ctx)
            return (tree_add(grads_acc, g), carry_), aux

        (grads, carry), auxs = jax.lax.scan(
            body, (tree_zeros_like(params), carry), chunks
        )
        grads = ctx.psum_tree(tree_scale(grads, 1.0 / k))
        return grads, _reduce_scanned_aux(auxs), carry


class RepCacheVJP:
    """GradCache's decomposed backprop (Gao et al. 2021): representations are
    computed chunk-wise without stored activations, the source's loss is
    differentiated w.r.t. the representations only (the "gradient cache"),
    and per-chunk VJPs inject those cotangents back through the encoders.
    Gradients are *exactly* the direct full-batch gradients of the same loss
    (tested) at chunked activation memory — composed with a bank source this
    yields full-batch backprop *plus* bank-extended negatives."""

    name = "rep_cache"

    def validate(self, cfg):
        if cfg.accumulation_steps < 1:
            raise ValueError("accumulation_steps must be >= 1")

    def compute(self, encoder, params, batch, source, carry, step, cfg, ctx):
        k = cfg.accumulation_steps
        chunks = _chunk_batch(batch, k)
        has_hard = batch.passage_hard is not None
        backend = resolve_loss_backend(cfg.loss_impl)

        # Stage 1: representation-only forward, chunk by chunk, no stored
        # activations for the loss graph (stop_gradient == GradCache's
        # torch.no_grad forward).
        def fwd(_, chunk):
            q, pp, ph = _encode_chunk(encoder, params, chunk)
            ph = jnp.zeros((0, q.shape[-1]), q.dtype) if ph is None else ph
            return None, (q, pp, ph)

        _, (qs, pps, phs) = jax.lax.scan(fwd, None, chunks)
        # the cached representation store lives in the policy's compute dtype
        # (bf16 halves the (B_g + banks, d) cache this strategy carries)
        pol = cfg.resolved_precision()
        qs, pps, phs = (
            pol.cast_compute(jax.lax.stop_gradient(x)) for x in (qs, pps, phs)
        )

        def merge(x):  # (K, local, d) -> (K*local, d)
            return x.reshape((-1, x.shape[-1]))

        # Stage 2: d loss / d representations (the "gradient cache"), with
        # the source's extra columns/rows in the matrix.
        def rep_loss(q_all, pp_all, ph_all):
            return source.loss(
                q_all,
                pp_all,
                ph_all if has_hard else None,
                carry,
                cfg=cfg,
                ctx=ctx,
                backend=backend,
            )

        (_, aux), rep_grads = jax.value_and_grad(rep_loss, argnums=(0, 1, 2), has_aux=True)(
            merge(qs), merge(pps), merge(phs)
        )
        gq = rep_grads[0].reshape(qs.shape)
        gpp = rep_grads[1].reshape(pps.shape)
        gph = rep_grads[2].reshape(phs.shape)

        # Stage 3: per-chunk VJP through the encoders, seeded with the cached
        # representation gradients. Activations exist for one chunk at a time.
        def bwd(grads_acc, inp):
            chunk, (gq_k, gpp_k, gph_k) = inp

            def enc(p):
                q, pp, ph = _encode_chunk(encoder, p, chunk)
                ph = jnp.zeros((0, q.shape[-1]), q.dtype) if ph is None else ph
                return (q, pp, ph)

            outs, vjp_fn = jax.vjp(enc, params)
            # cached cotangents are in compute dtype; the encoder's native
            # output dtype may differ (fp32 towers under a bf16 policy) —
            # seed the VJP in the primal dtype it expects
            seeds = tuple(
                g.astype(o.dtype) for g, o in zip((gq_k, gpp_k, gph_k), outs)
            )
            (g,) = vjp_fn(seeds)
            return tree_add(grads_acc, g), None

        grads, _ = jax.lax.scan(
            bwd, tree_zeros_like(params), (chunks, (gq, gpp, gph))
        )
        grads = ctx.psum_tree(grads)
        carry = source.push(carry, aux, step, cfg=cfg, ctx=ctx)
        return grads, aux, carry


# --------------------------------------------------------------------------
# Registries + resolution
# --------------------------------------------------------------------------
SOURCES: dict[str, NegativeSource] = {
    s.name: s
    for s in (
        InBatchNegatives(),
        MinedNegatives(),
        GatheredInBatch(),
        DualBankNegatives(),
        PassageBankNegatives(),
    )
}

STRATEGIES: dict[str, BackpropStrategy] = {
    s.name: s for s in (DirectBackprop(), ScanAccumulate(), RepCacheVJP())
}

# method name -> (negatives, backprop). The first four are the paper's
# methods (gradient-exact vs. the original implementations); the rest are
# compositions the monolithic API could not express.
COMPOSITIONS: dict[str, Tuple[str, str]] = {
    "dpr": ("in_batch", "direct"),
    "grad_accum": ("in_batch", "scan"),
    "grad_cache": ("in_batch", "rep_cache"),
    "contaccum": ("dual_bank", "scan"),
    "contcache": ("dual_bank", "rep_cache"),
    "prebatch": ("passage_bank", "scan"),
    "prebatch_cache": ("passage_bank", "rep_cache"),
    "dpr_xdev": ("gathered", "direct"),
    "mined": ("mined", "direct"),
    "mined_accum": ("mined", "scan"),
    "mined_cache": ("mined", "rep_cache"),
}


def available_methods() -> list[str]:
    """Registered method names (legacy four + new compositions)."""
    return sorted(COMPOSITIONS)


def method_composition(method: str) -> Tuple[str, str]:
    """Legacy-string resolution: method name -> (negatives, backprop)."""
    if method not in COMPOSITIONS:
        raise ValueError(
            f"unknown method {method!r}; one of {available_methods()}"
        )
    return COMPOSITIONS[method]


def method_uses_banks(method: str) -> bool:
    """Does this method's negative source read/write the FIFO banks?"""
    return SOURCES[method_composition(method)[0]].uses_banks


def method_needs_mesh(method: str) -> bool:
    """Does this method's negative source require cfg.dp_axis (a mesh)?"""
    return SOURCES[method_composition(method)[0]].needs_mesh


def resolve_composition(cfg: ContrastiveConfig) -> Tuple[NegativeSource, BackpropStrategy]:
    """cfg -> (source, strategy). Explicit ``negatives=``/``backprop=``
    fields win; unset fields fall back to the legacy ``method=`` string."""
    neg, bp = cfg.resolved_composition_names()
    if neg not in SOURCES:
        raise ValueError(f"unknown negatives {neg!r}; one of {sorted(SOURCES)}")
    if bp not in STRATEGIES:
        raise ValueError(f"unknown backprop {bp!r}; one of {sorted(STRATEGIES)}")
    return SOURCES[neg], STRATEGIES[bp]


# --------------------------------------------------------------------------
# The generic program builder
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StepProgram:
    """A built contrastive update: ``update(state, batch) -> (state,
    StepMetrics)`` plus the composition it was built from."""

    update: Callable[[ContrastiveState, RetrievalBatch], Tuple[ContrastiveState, StepMetrics]]
    source: NegativeSource
    strategy: BackpropStrategy
    cfg: ContrastiveConfig

    @property
    def name(self) -> str:
        for m, (neg, bp) in COMPOSITIONS.items():
            if (neg, bp) == (self.source.name, self.strategy.name):
                return m
        return f"{self.source.name}*{self.strategy.name}"


def _metrics(
    grads,
    aux: LossAux,
    bank_q: BankState,
    bank_p: BankState,
    *,
    ctx: Optional[DistCtx] = None,
    sharded_banks: bool = False,
) -> StepMetrics:
    gq = subtree_norm(grads, "query")
    gp = subtree_norm(grads, "passage")

    def fill(bank: BankState) -> jnp.ndarray:
        if not bank.buf.shape[0]:
            return jnp.zeros(())
        f = bank.valid.sum().astype(STATS_DTYPE)
        # shard-local fills differ across devices mid-warm-up (low ring slots
        # fill first); psum to the replicated global fill
        return ctx.psum(f) if sharded_banks and ctx is not None else f

    return StepMetrics(
        loss=aux.loss,
        accuracy=aux.accuracy,
        grad_norm=tree_global_norm(grads),
        grad_norm_query=gq,
        grad_norm_passage=gp,
        grad_norm_ratio=gp / jnp.maximum(gq, 1e-12),
        n_negatives=aux.n_negatives,
        bank_fill_q=fill(bank_q),
        bank_fill_p=fill(bank_p),
    )


def _apply(state: ContrastiveState, grads, tx, bank_q, bank_p) -> ContrastiveState:
    updates, opt_state = tx.update(grads, state.opt_state, state.params)
    params = apply_updates(state.params, updates)
    return ContrastiveState(
        step=state.step + 1,
        params=params,
        opt_state=opt_state,
        bank_q=bank_q,
        bank_p=bank_p,
    )


def build_step_program(
    encoder: DualEncoder, tx: GradientTransformation, cfg: ContrastiveConfig
) -> StepProgram:
    """Compose cfg's negative source and backprop strategy into one update
    program. The program owns chunking, loss assembly, bank pushes, the
    optimizer application and metric assembly; it is pure and serves
    single-device, shard_map/GSPMD and dry-run paths unchanged.
    ``cfg.loss_impl`` selects the loss backend (dense einsum vs the fused
    Pallas kernel) orthogonally to the composition."""
    source, strategy = resolve_composition(cfg)
    source.validate(cfg)
    strategy.validate(cfg)
    resolve_loss_backend(cfg.loss_impl)  # fail fast on unknown loss_impl
    resolve_precision(cfg.precision)     # fail fast on unknown precision
    ctx = DistCtx(cfg.dp_axis)

    def update(state: ContrastiveState, batch: RetrievalBatch):
        carry = source.begin(state, cfg)
        grads, aux, carry = strategy.compute(
            encoder, state.params, batch, source, carry, state.step, cfg, ctx
        )
        bank_q, bank_p = carry
        new_state = _apply(state, grads, tx, bank_q, bank_p)
        return new_state, _metrics(
            grads, aux, bank_q, bank_p,
            ctx=ctx, sharded_banks=cfg.shard_banks and ctx.is_distributed,
        )

    return StepProgram(update=update, source=source, strategy=strategy, cfg=cfg)


def init_state(
    rng: jax.Array,
    encoder: DualEncoder,
    tx: GradientTransformation,
    cfg: ContrastiveConfig,
    params: Optional[Any] = None,
    bank_dim: Optional[int] = None,
) -> ContrastiveState:
    """Initial train state with the bank capacities the cfg's negative
    source asks for; bank rings are allocated in the precision policy's
    ``bank_dtype`` (or the explicit ``cfg.bank_dtype`` override)."""
    if params is None:
        params = encoder.init(rng)
    source, _ = resolve_composition(cfg)
    nq, np_ = source.bank_sizes(cfg)
    d = bank_dim or encoder.rep_dim
    bank_dtype = cfg.resolved_bank_dtype()
    return ContrastiveState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=tx.init(params),
        bank_q=init_bank(nq, d, bank_dtype),
        bank_p=init_bank(np_, d, bank_dtype),
    )
