"""Batched retrieval serving: the inference half of the framework.

Dense-retrieval serving has two phases (mirroring the paper's task):

  * **Offline corpus build** — encode every passage with the passage tower in
    fixed-size batches (`build_index`), store the matrix. At pod scale the
    batch is sharded over the DP axes like training.
  * **Online query serving** — a `RequestQueue` + `BatchingServer` pair:
    requests arrive singly, the server coalesces them up to ``max_batch`` or
    ``max_wait_s`` (classic dynamic batching), encodes with the query tower,
    and scores against the index with an exact blocked top-k (the FAISS exact
    path the paper uses, expressed as a jit-compiled matmul+top_k so it also
    serves the recsys ``retrieval_cand`` shape).

Fault-tolerance notes: the server is stateless between batches — a restart
replays only in-flight requests (callers time out and retry); the index is a
checkpointed artifact.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------- exact top-k
def blocked_topk_scores(
    query_reps: jnp.ndarray,      # (Q, d)
    index: jnp.ndarray,           # (N, d)
    k: int,
    *,
    block: int = 65536,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k by blocked matmul + running merge — never materializes the
    full (Q, N) score matrix. Returns (scores (Q, k), ids (Q, k))."""
    n = index.shape[0]
    block = min(block, n)
    n_blocks = (n + block - 1) // block
    pad = n_blocks * block - n
    if pad:
        index = jnp.pad(index, ((0, pad), (0, 0)))
    blocks = index.reshape(n_blocks, block, -1)

    def body(carry, inp):
        best_s, best_i = carry
        blk, b0 = inp
        s = query_reps @ blk.T                                   # (Q, block)
        ids = b0 + jnp.arange(block, dtype=jnp.int32)[None, :]
        s = jnp.where(ids < n, s, -jnp.inf)
        cat_s = jnp.concatenate([best_s, s], axis=1)
        cat_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, s.shape)], axis=1)
        top_s, pos = jax.lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, pos, axis=1)
        return (top_s, top_i), None

    q = query_reps.shape[0]
    init = (
        jnp.full((q, k), -jnp.inf, query_reps.dtype),
        jnp.zeros((q, k), jnp.int32),
    )
    offsets = jnp.arange(n_blocks, dtype=jnp.int32) * block
    (scores, ids), _ = jax.lax.scan(body, init, (blocks, offsets))
    return scores, ids


def build_index(
    encode_passage: Callable[[Any], jnp.ndarray],
    passages: np.ndarray,
    *,
    batch: int = 256,
) -> np.ndarray:
    """Encode a corpus in fixed batches (pads the tail so one compiled shape
    serves the whole build)."""
    n = len(passages)
    out: List[np.ndarray] = []
    for lo in range(0, n, batch):
        chunk = passages[lo : lo + batch]
        if len(chunk) < batch:
            chunk = np.concatenate(
                [chunk, np.repeat(chunk[-1:], batch - len(chunk), axis=0)]
            )
        out.append(np.asarray(encode_passage(chunk)))
    return np.concatenate(out)[:n]


# ----------------------------------------------------------- dynamic batching
@dataclasses.dataclass
class Request:
    payload: np.ndarray
    future: "queue.Queue"        # 1-slot: receives (ids, scores) or Exception
    t_enqueue: float = dataclasses.field(default_factory=time.monotonic)


class BatchingServer:
    """Dynamic batcher: coalesce requests to ``max_batch`` (padding to the
    compiled batch size) or flush after ``max_wait_s``."""

    def __init__(
        self,
        serve_fn: Callable[[np.ndarray], Tuple[np.ndarray, np.ndarray]],
        *,
        max_batch: int = 32,
        max_wait_s: float = 0.01,
    ):
        self.serve_fn = serve_fn
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.batch_sizes: List[int] = []   # observability: coalescing histogram

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)

    def submit(self, payload: np.ndarray) -> "queue.Queue":
        fut: "queue.Queue" = queue.Queue(maxsize=1)
        self._q.put(Request(payload=payload, future=fut))
        return fut

    def query(self, payload: np.ndarray, timeout: float = 30.0):
        res = self.submit(payload).get(timeout=timeout)
        if isinstance(res, Exception):
            raise res
        return res

    # -- internals ---------------------------------------------------------
    def _collect(self) -> List[Request]:
        try:
            first = self._q.get(timeout=0.05)
        except queue.Empty:
            return []
        batch = [first]
        deadline = first.t_enqueue + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                batch.append(self._q.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _loop(self):
        while not self._stop.is_set():
            batch = self._collect()
            if not batch:
                continue
            self.batch_sizes.append(len(batch))
            payloads = np.stack([r.payload for r in batch])
            n = len(batch)
            if n < self.max_batch:  # pad to the compiled shape
                payloads = np.concatenate(
                    [payloads, np.repeat(payloads[-1:], self.max_batch - n, axis=0)]
                )
            try:
                ids, scores = self.serve_fn(payloads)
                ids, scores = np.asarray(ids), np.asarray(scores)
                for i, r in enumerate(batch):
                    r.future.put((ids[i], scores[i]))
            except Exception as e:  # pragma: no cover - surfaced to callers
                for r in batch:
                    r.future.put(e)


def make_retrieval_server(
    encode_query: Callable[[np.ndarray], jnp.ndarray],
    index: np.ndarray,
    *,
    k: int = 20,
    max_batch: int = 32,
    max_wait_s: float = 0.01,
) -> BatchingServer:
    index_dev = jnp.asarray(index)

    @jax.jit
    def _serve(tokens):
        reps = encode_query(tokens)
        scores, ids = blocked_topk_scores(reps, index_dev, k)
        return ids, scores

    return BatchingServer(_serve, max_batch=max_batch, max_wait_s=max_wait_s)
