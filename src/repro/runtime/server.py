"""Dynamic batching for retrieval serving.

``BatchingServer`` coalesces single-query requests up to ``max_batch``
(padding to the compiled batch shape) or flushes after ``max_wait_s`` —
classic dynamic batching. The model-side machinery (index build, sharded
scoring, precision) lives in the Retriever API (``repro/retrieval``);
``retrieval.serving.make_server`` wires a Retriever to this server, and the
legacy helpers below (``blocked_topk_scores``, ``build_index``,
``make_retrieval_server``) are thin wrappers kept for existing callers.

Fault-tolerance notes: the server is stateless between batches — a restart
replays only in-flight requests (callers time out and retry); the index is a
checkpointed artifact.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------- exact top-k
def blocked_topk_scores(
    query_reps: jnp.ndarray,      # (Q, d)
    index: jnp.ndarray,           # (N, d)
    k: int,
    *,
    block: int = 65536,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact top-k by blocked matmul + running merge — never materializes the
    full (Q, N) score matrix. Returns (scores (Q, k), ids (Q, k)); ids are
    -1 (scores NEG_INF) for slots beyond the index size when k > N.

    Legacy entry point: the implementation is the 'dense' SearchBackend in
    repro/retrieval/search.py (lazy import breaks the runtime <-> retrieval
    cycle: retrieval.serving builds on BatchingServer below)."""
    from repro.retrieval.search import DenseSearchBackend

    return DenseSearchBackend(block=block).topk(query_reps, index, k)


def build_index(
    encode_passage: Callable[[Any], jnp.ndarray],
    passages: np.ndarray,
    *,
    batch: int = 256,
) -> np.ndarray:
    """Legacy fixed-batch corpus encode (see repro/retrieval/index.py)."""
    from repro.retrieval.index import encode_corpus

    return encode_corpus(encode_passage, passages, batch=batch)


# ----------------------------------------------------------- dynamic batching
@dataclasses.dataclass
class Request:
    payload: np.ndarray
    future: "queue.Queue"        # 1-slot: receives (ids, scores) or Exception
    t_enqueue: float = dataclasses.field(default_factory=time.monotonic)


class BatchingServer:
    """Dynamic batcher: coalesce requests to ``max_batch`` (padding to the
    compiled batch size) or flush after ``max_wait_s``."""

    def __init__(
        self,
        serve_fn: Callable[[np.ndarray], Tuple[np.ndarray, np.ndarray]],
        *,
        max_batch: int = 32,
        max_wait_s: float = 0.01,
    ):
        self.serve_fn = serve_fn
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._q: "queue.Queue[Request]" = queue.Queue()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.batch_sizes: List[int] = []   # observability: coalescing histogram

    def start(self):
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=5)

    def submit(self, payload: np.ndarray) -> "queue.Queue":
        fut: "queue.Queue" = queue.Queue(maxsize=1)
        self._q.put(Request(payload=payload, future=fut))
        return fut

    def query(self, payload: np.ndarray, timeout: float = 30.0):
        res = self.submit(payload).get(timeout=timeout)
        if isinstance(res, Exception):
            raise res
        return res

    # -- internals ---------------------------------------------------------
    def _collect(self) -> List[Request]:
        try:
            first = self._q.get(timeout=0.05)
        except queue.Empty:
            return []
        batch = [first]
        # Drain whatever is already queued without waiting: under backlog the
        # batch fills instantly. (The old deadline was first.t_enqueue +
        # max_wait_s — submit time, not collect time — so a backed-up queue
        # made remaining <= 0 on the first iteration and every batch
        # degraded to size 1, exactly when coalescing matters most.)
        while len(batch) < self.max_batch:
            try:
                batch.append(self._q.get_nowait())
            except queue.Empty:
                break
        # then wait out the remainder of the coalescing window, measured
        # from collect time, for stragglers
        deadline = time.monotonic() + self.max_wait_s
        while len(batch) < self.max_batch:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                batch.append(self._q.get(timeout=remaining))
            except queue.Empty:
                break
        return batch

    def _loop(self):
        while not self._stop.is_set():
            batch = self._collect()
            if not batch:
                continue
            self.batch_sizes.append(len(batch))
            payloads = np.stack([r.payload for r in batch])
            n = len(batch)
            if n < self.max_batch:  # pad to the compiled shape
                payloads = np.concatenate(
                    [payloads, np.repeat(payloads[-1:], self.max_batch - n, axis=0)]
                )
            try:
                ids, scores = self.serve_fn(payloads)
                ids, scores = np.asarray(ids), np.asarray(scores)
                for i, r in enumerate(batch):
                    r.future.put((ids[i], scores[i]))
            except Exception as e:  # pragma: no cover - surfaced to callers
                for r in batch:
                    r.future.put(e)


def make_retrieval_server(
    encode_query: Callable[[np.ndarray], jnp.ndarray],
    index: np.ndarray,
    *,
    k: int = 20,
    max_batch: int = 32,
    max_wait_s: float = 0.01,
) -> BatchingServer:
    """Legacy raw-matrix server; prefer retrieval.serving.make_server (the
    Retriever-backed path: checkpoint load, sharding, precision, backends)."""
    index_dev = jnp.asarray(index)

    @jax.jit
    def _serve(tokens):
        reps = encode_query(tokens)
        scores, ids = blocked_topk_scores(reps, index_dev, k)
        return ids, scores

    return BatchingServer(_serve, max_batch=max_batch, max_wait_s=max_wait_s)
