"""Runtime layer: fault-tolerant trainer + batched retrieval server."""

from repro.runtime.trainer import Trainer, TrainerConfig, TrainerReport, StepFailure
from repro.runtime.server import (
    BatchingServer,
    blocked_topk_scores,
    build_index,
    make_retrieval_server,
)

__all__ = [
    "Trainer",
    "TrainerConfig",
    "TrainerReport",
    "StepFailure",
    "BatchingServer",
    "blocked_topk_scores",
    "build_index",
    "make_retrieval_server",
]
