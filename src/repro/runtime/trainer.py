"""Fault-tolerant training loop.

Production concerns handled here (the update step itself stays pure and
jit-compiled — see core/methods.py and launch/steps.py):

  * **Checkpoint/restart** — periodic async checkpoints of (train state,
    loader state); on start the trainer resumes from the newest valid
    checkpoint, skipping corrupt/partial ones (checkpoint/checkpoint.py).
  * **Step-level fault tolerance** — a failing step (device error, NaN loss
    if ``abort_on_nan``) triggers restore-from-last-checkpoint and replay,
    up to ``max_restarts`` times. Fault-injection hooks make this testable.
  * **Straggler watchdog** — per-step wall time is tracked with an EMA; steps
    slower than ``straggler_factor`` x EMA are logged with their step index
    (on a real pod the log feeds the reshard-and-restart runbook; here it is
    also the hook tests use).
  * **Preemption handling** — ``request_stop()`` (wire to SIGTERM in the
    launcher) finishes the current step, writes a final checkpoint, exits
    cleanly.

The trainer is deliberately agnostic of what the step computes: it takes
``step_fn(state, batch) -> (state, metrics)`` plus a ``next_batch()``
callable, so the same loop drives the paper's ContAccum dual-encoder runs,
the causal-LM cells, GNN and recsys training.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager, latest_step
from repro.data.loader import LoaderState


@dataclasses.dataclass
class TrainerConfig:
    """Loop-level knobs only — *what* a step computes (the method
    composition, the loss backend, the PrecisionPolicy) lives entirely in
    the jitted ``step_fn`` the trainer is handed (core/step_program.py), so
    every precision preset checkpoints, restores and replays through this
    loop unchanged: the checkpoint payload carries the state's dtypes (bf16
    bank rings included), and ``abort_on_nan`` reads the fp32 loss metric
    the accum-dtype contract guarantees.

    total_steps: run length in optimizer updates.
    checkpoint_dir/checkpoint_every/keep_checkpoints: periodic async
        checkpoints of (train state, loader state); None disables.
    max_restarts: restore-and-replay budget for failing steps.
    straggler_factor/straggler_warmup/ema_decay: step-time watchdog (steps
        slower than factor x EMA are logged after the warm-up).
    abort_on_nan: treat a non-finite loss as a step failure (restore).
    log_every: metric print cadence.
    eval_every: periodic-eval cadence (0 disables). Every ``eval_every``
        steps the trainer calls its ``eval_fn(state, step) -> dict`` hook —
        the ANCE-style loop of re-encoding and searching the corpus with
        the *training-time* encoder (wire it to
        ``repro.evaluation.evaluate_topk`` via a Retriever). Results are
        merged into the step's history row under ``eval/`` keys.
        ``eval_every``/``eval_fn`` are sugar over the generic ``hooks=``
        mechanism below (a ``PeriodicHook(prefix='eval/')``); the mining
        refresh (repro/mining ``HardNegativeMiner.refresh_hook``) rides the
        same mechanism, so eval and miner refresh share one cadence path.
    """

    total_steps: int
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    straggler_warmup: int = 5          # steps before the EMA is trusted
    ema_decay: float = 0.9
    abort_on_nan: bool = True
    log_every: int = 10
    eval_every: int = 0


class StepFailure(RuntimeError):
    """Raised inside the loop to trigger restore-and-replay."""


@dataclasses.dataclass
class PeriodicHook:
    """A callback the loop fires every ``every`` steps (after the step, when
    ``(step + 1) % every == 0``; 0 disables).

    ``fn(state, step)`` may return a metric dict — values are merged into
    the step's history row under ``prefix``. ``advisory`` hooks (eval,
    miner refresh) must never consume the restore-and-replay budget of the
    training path: their exceptions are logged and swallowed (a
    deterministic hook error would otherwise replay the same healthy step
    until max_restarts kills the run). Non-advisory hooks raise
    ``StepFailure`` and go through the normal restore path."""

    every: int
    fn: Callable[[Any, int], Optional[Dict[str, float]]]
    prefix: str = ""
    name: str = "hook"
    advisory: bool = True


@dataclasses.dataclass
class TrainerReport:
    steps_run: int
    restarts: int
    stragglers: List[int]
    final_metrics: Dict[str, float]
    history: List[Dict[str, float]]


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        step_fn: Callable[[Any, Any], Any],
        next_batch: Callable[[int], Any],
        *,
        loader_state: Optional[LoaderState] = None,
        eval_fn: Optional[Callable[[Any, int], Dict[str, float]]] = None,
        hooks: Sequence[PeriodicHook] = (),
        aux_state: Optional[Any] = None,
        # test hooks ------------------------------------------------------
        fault_hook: Optional[Callable[[int], None]] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.next_batch = next_batch
        self.loader_state = loader_state or LoaderState()
        self.eval_fn = eval_fn
        # aux_state: an optional side object riding the checkpoint payload —
        # anything with state_to_save() -> fixed-structure np pytree and
        # load_saved_state(tree) (e.g. the mining subsystem's table)
        self.aux_state = aux_state
        self._hooks: List[PeriodicHook] = list(hooks)
        if eval_fn is not None:
            # legacy sugar: eval is just one more periodic hook
            self._hooks.append(
                PeriodicHook(
                    every=cfg.eval_every, fn=eval_fn, prefix="eval/", name="eval"
                )
            )
        self.fault_hook = fault_hook
        self.clock = clock
        self._stop = False
        self.stragglers: List[int] = []
        self.restarts = 0
        self.history: List[Dict[str, float]] = []
        self._ckpt = (
            CheckpointManager(
                cfg.checkpoint_dir, keep=cfg.keep_checkpoints, async_save=True
            )
            if cfg.checkpoint_dir
            else None
        )

    # -- public control -----------------------------------------------------
    def request_stop(self):
        """Preemption notice: finish the current step, checkpoint, exit."""
        self._stop = True

    # -- checkpoint plumbing --------------------------------------------------
    def _save(self, step: int, state, *, block: bool = False):
        if self._ckpt is None:
            return
        ls = self.loader_state
        payload = {
            "state": state,
            "loader": np.asarray(
                [ls.epoch, ls.step, ls.mined_step, ls.mined_version], np.int64
            ),
        }
        if self.aux_state is not None:
            payload["aux"] = self.aux_state.state_to_save()
        self._ckpt.save(step, payload, block=block)

    def _restore(self, template_state):
        if self._ckpt is None or latest_step(self.cfg.checkpoint_dir) is None:
            return None
        payload = {
            "state": template_state,
            "loader": np.zeros((4,), np.int64),
        }
        if self.aux_state is not None:
            # the current aux pytree is its own template (fixed structure)
            payload["aux"] = self.aux_state.state_to_save()
        restored, step = self._ckpt.restore_latest(payload)
        ls = self.loader_state
        ls.epoch, ls.step, ls.mined_step, ls.mined_version = (
            int(v) for v in restored["loader"]
        )
        if self.aux_state is not None:
            self.aux_state.load_saved_state(restored["aux"])
        return restored["state"], step

    # -- the loop -------------------------------------------------------------
    def run(self, state) -> tuple[Any, TrainerReport]:
        cfg = self.cfg
        start = 0
        resumed = self._restore(state)
        if resumed is not None:
            state, start = resumed
            start += 1

        ema = None
        step = start
        last_metrics: Dict[str, float] = {}
        while step < cfg.total_steps and not self._stop:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)  # may raise (injected fault)
                batch = self.next_batch(step)
                t0 = self.clock()
                state, metrics = self.step_fn(state, batch)
                metrics = jax.device_get(metrics)
                dt = self.clock() - t0

                if cfg.abort_on_nan:
                    loss = float(np.asarray(getattr(metrics, "loss", metrics.get("loss", 0.0)) if isinstance(metrics, dict) else metrics.loss))
                    if not np.isfinite(loss):
                        raise StepFailure(f"non-finite loss at step {step}: {loss}")

                # straggler watchdog
                if ema is not None and step - start >= cfg.straggler_warmup:
                    if dt > cfg.straggler_factor * ema:
                        self.stragglers.append(step)
                ema = dt if ema is None else cfg.ema_decay * ema + (1 - cfg.ema_decay) * dt

                last_metrics = self._log(step, metrics, dt)
                for hook in self._hooks:
                    if not hook.every or (step + 1) % hook.every:
                        continue
                    try:
                        res = hook.fn(state, step)
                    except Exception as e:
                        if not hook.advisory:
                            raise StepFailure(
                                f"{hook.name} hook failed at step {step}: {e}"
                            ) from e
                        print(f"step {step}: {hook.name} failed ({e})", flush=True)
                    else:
                        vals = {
                            f"{hook.prefix}{k}": float(v)
                            for k, v in (res or {}).items()
                        }
                        if vals:
                            last_metrics.update(vals)  # history row, in place
                            msg = " ".join(
                                f"{k}={v:.4f}" for k, v in vals.items()
                            )
                            print(f"step {step}: {msg}", flush=True)
                if cfg.checkpoint_dir and (step + 1) % cfg.checkpoint_every == 0:
                    self._save(step, state)
                step += 1
            except (StepFailure, jax.errors.JaxRuntimeError, FloatingPointError) as e:
                self.restarts += 1
                if self.restarts > cfg.max_restarts or self._ckpt is None:
                    raise
                resumed = self._restore(state)
                if resumed is None:
                    raise RuntimeError(
                        f"step {step} failed ({e}) with no checkpoint to restore"
                    ) from e
                state, ck_step = resumed
                step = ck_step + 1

        if self._ckpt is not None:
            self._save(max(step - 1, 0), state, block=True)
            self._ckpt.wait()
        return state, TrainerReport(
            steps_run=step - start,
            restarts=self.restarts,
            stragglers=self.stragglers,
            final_metrics=last_metrics,
            history=self.history,
        )

    def _log(self, step: int, metrics, dt: float) -> Dict[str, float]:
        if isinstance(metrics, dict):
            flat = {k: float(np.asarray(v)) for k, v in metrics.items()
                    if np.ndim(v) == 0}
        else:  # NamedTuple (StepMetrics)
            flat = {
                k: float(np.asarray(v))
                for k, v in metrics._asdict().items()
                if np.ndim(v) == 0
            }
        flat["step"] = step
        flat["step_time_s"] = dt
        self.history.append(flat)
        if step % self.cfg.log_every == 0:
            keys = [k for k in ("loss", "accuracy", "grad_norm_ratio") if k in flat]
            msg = " ".join(f"{k}={flat[k]:.4f}" for k in keys)
            print(f"step {step}: {msg} ({dt*1e3:.1f} ms)", flush=True)
        return flat
