"""Asynchronous hard-negative mining: the training<->serving connector.

ANCE-style (Xiong et al. 2020) periodic re-encode + ANN mining, run through
the repro.retrieval serving stack *during* training:

  * ``MinerConfig`` (config.py) — refresh cadence, mining depth, the
    teleportation trust region (Sun et al. 2022), and the passthrough axes
    (search backend / index layout / precision) of the ``RetrieverConfig``
    the miner builds its index with.
  * ``NegativeTable`` / ``NegativeTableBuffer`` (table.py) — the
    double-buffered per-query id table the loader joins against; publication
    is one atomic reference swap, so batch assembly never blocks on a
    refresh and never observes a half-written table.
  * ``HardNegativeMiner`` (miner.py) — snapshots training params, re-encodes
    the corpus into an ``IndexStore``, mines top-k per training query via
    the dense/fused ``SearchBackend``, filters gold + applies teleportation
    banding, and publishes the table — synchronously (deterministic tests)
    or on a background thread overlapped with training steps.

The mined ids enter training as extra ``passage_hard`` columns
(data/loader.py ``MinedNegativeInjector``), so ``negatives="mined"``
composes with every BackpropStrategy and with the dual memory banks
(core/step_program.py ``MinedNegatives``).
"""

from repro.mining.config import MinerConfig
from repro.mining.miner import HardNegativeMiner, teleport_filter
from repro.mining.table import NegativeTable, NegativeTableBuffer, empty_table

__all__ = [
    "MinerConfig",
    "HardNegativeMiner",
    "NegativeTable",
    "NegativeTableBuffer",
    "empty_table",
    "teleport_filter",
]
