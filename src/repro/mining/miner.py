"""HardNegativeMiner: periodic ANCE-style refresh through the serving stack.

One refresh = snapshot the training params to host memory, re-encode the
corpus into an ``IndexStore`` with the passage tower, mine top-k per
training query with the dense/fused ``SearchBackend``, drop gold passages,
apply the teleportation trust region (band + score margin), and publish the
resulting ``NegativeTable`` with an atomic buffer swap.

Two execution modes (cfg.sync):

  * **async** (default) — the refresh runs on a background thread against
    the param *snapshot*; training steps keep dispatching concurrently and
    the loader keeps serving the previous table until the swap. Worker
    exceptions are captured and re-raised on the consumer side at the next
    miner call (the PrefetchIterator contract). A refresh request arriving
    while one is in flight is skipped (counted), never queued — mining
    depth-2 stale tables helps nobody.
  * **sync** — the refresh blocks the caller. Deterministic: same params,
    same corpus, same config => bit-identical table (tests/test_mining.py).

The whole pipeline is intentionally host-side (numpy tables, a thread, an
index rebuild): calling any refresh entry point from jitted code would run
it once at trace time and bake a stale table in as a constant — reprolint's
RPL005 mining extension flags exactly that.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core.types import DualEncoder
from repro.mining.config import MinerConfig
from repro.mining.table import NegativeTable, NegativeTableBuffer, empty_table
from repro.retrieval.retriever import Retriever


def teleport_filter(
    ids: np.ndarray,
    scores: np.ndarray,
    gold: np.ndarray,
    *,
    depth_lo: int,
    depth_hi: int,
    margin: float,
    n_out: int,
) -> np.ndarray:
    """Teleportation filtering of ranked candidates (Sun et al. 2022).

    ids/scores: (Q, K) ranked best-first (the SearchBackend contract);
    ids -1 = empty. gold: (Q,) gold passage id per query. Per row:

      1. drop empty slots and the gold passage;
      2. rank the survivors 0..; keep ranks in ``[depth_lo, depth_hi)``
         (the band — skipping the very top keeps negatives in the trust
         region);
      3. drop banded candidates scoring within ``margin`` of the reference
         score (gold's score when gold was retrieved, else the top score) —
         likely unlabeled positives. margin=0.0 still drops candidates
         scoring >= the reference.

    Returns (Q, n_out) int32; rows with fewer survivors pad with -1.
    """
    ids = np.asarray(ids)
    scores = np.asarray(scores)
    gold = np.asarray(gold)
    q, _ = ids.shape
    out = np.full((q, n_out), -1, np.int32)
    is_gold = ids == gold[:, None]
    valid = (ids >= 0) & ~is_gold
    # reference score: gold's if retrieved, else the best retrieved score
    has_gold = is_gold.any(axis=1)
    gold_score = np.where(is_gold, scores, -np.inf).max(axis=1)
    ref = np.where(has_gold, gold_score, scores[:, 0])
    # gold-excluded rank of each retained candidate
    rank = np.cumsum(valid, axis=1) - 1
    keep = valid & (rank >= depth_lo) & (rank < depth_hi) & (scores < ref[:, None] - margin)
    for i in range(q):
        row = ids[i, keep[i]][:n_out]
        out[i, : len(row)] = row
    return out


def _host_snapshot(params: Any) -> Any:
    """Pull the param pytree to host memory: the refresh must not hold
    references into device buffers the optimizer is about to overwrite, and
    the background thread must not race device placement with training."""
    return jax.device_get(params)


class HardNegativeMiner:
    """Owns the refresh pipeline + the published ``NegativeTableBuffer``.

    Built from the *training* DualEncoder and the mining corpus arrays:
    ``queries`` (Nq, q_len) token rows aligned with the loader's dataset
    indices, ``passages`` (Np, p_len), and ``gold`` (Nq,) gold passage id
    per query (defaults to ``arange`` — the SyntheticRetrievalCorpus
    alignment). The internal Retriever is persistent: its jitted encode and
    search programs compile once and every refresh reuses them (the rebuild
    only re-runs the encode).
    """

    def __init__(
        self,
        encoder: DualEncoder,
        cfg: MinerConfig,
        *,
        queries: np.ndarray,
        passages: np.ndarray,
        gold: Optional[np.ndarray] = None,
        mesh=None,
    ):
        cfg.validate()
        self.cfg = cfg
        self.queries = np.asarray(queries)
        self.passages = np.asarray(passages)
        self.gold = (
            np.arange(len(self.queries), dtype=np.int64)
            if gold is None
            else np.asarray(gold)
        )
        if len(self.gold) != len(self.queries):
            raise ValueError(
                f"gold has {len(self.gold)} rows for {len(self.queries)} queries"
            )
        self.buffer = NegativeTableBuffer(
            empty_table(len(self.queries), cfg.n_negatives)
        )
        self.retriever = Retriever(encoder, None, cfg.retriever_config(), mesh=mesh)
        self._thread: Optional[threading.Thread] = None
        self._exc: Optional[BaseException] = None
        self._observed_step = 0  # latest training step seen by note_step()
        self.refreshes = 0       # published refreshes
        self.skipped = 0         # requests dropped because one was in flight
        self.last_overlap = 0    # training steps observed during the last refresh

    # ------------------------------------------------------------- mining
    def _mine(self, params: Any, step: int) -> NegativeTable:
        """One complete refresh against a host param snapshot (any thread)."""
        cfg = self.cfg
        r = self.retriever
        r.params = params
        r.build_index(self.passages)  # the ANCE re-encode
        nq = len(self.queries)
        qb = min(cfg.query_batch, nq)
        ids = np.full((nq, cfg.top_k), -1, np.int32)
        scores = np.full((nq, cfg.top_k), -np.inf, np.float32)
        for lo in range(0, nq, qb):
            chunk = self.queries[lo : lo + qb]
            n = len(chunk)
            if n < qb:  # pad the tail to the one compiled search shape
                pad = np.zeros((qb - n,) + chunk.shape[1:], chunk.dtype)
                chunk = np.concatenate([chunk, pad])
            cid, csc = r.search(chunk)
            ids[lo : lo + n] = cid[:n]
            scores[lo : lo + n] = csc[:n]
        mined = teleport_filter(
            ids,
            scores,
            self.gold,
            depth_lo=cfg.depth_lo,
            depth_hi=cfg.depth_hi,
            margin=cfg.margin,
            n_out=cfg.n_negatives,
        )
        return NegativeTable(
            ids=mined, step=step, version=self.buffer.read().version + 1
        )

    def _publish(self, table: NegativeTable, start_step: int) -> None:
        self.buffer.swap(table)
        self.last_overlap = max(self._observed_step - start_step, 0)
        self.refreshes += 1

    # ---------------------------------------------------------- refresh API
    def refresh(self, params: Any, step: int) -> NegativeTable:
        """Synchronous refresh: blocks until the new table is published.
        Drains any in-flight async refresh first (one refresh at a time)."""
        self.wait()
        table = self._mine(_host_snapshot(params), int(step))
        self._publish(table, int(step))
        return table

    def refresh_async(self, params: Any, step: int) -> bool:
        """Kick off a background refresh against a snapshot of ``params``.
        Returns False (and counts a skip) if one is already in flight.
        Re-raises a previous worker failure on this (consumer) thread."""
        self._raise_pending()
        if self._thread is not None:
            if self._thread.is_alive():
                self.skipped += 1
                return False
            self._thread.join()
        snapshot = _host_snapshot(params)  # on the caller's thread, pre-fork
        start = int(step)

        def work():
            try:
                self._publish(self._mine(snapshot, start), start)
            except BaseException as e:  # re-raised at the next consumer call
                self._exc = e

        self._thread = threading.Thread(
            target=work, name="hard-negative-miner", daemon=True
        )
        self._thread.start()
        return True

    def in_flight(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def wait(self) -> None:
        """Barrier: join any in-flight refresh, then surface its failure."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_pending()

    def _raise_pending(self) -> None:
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def note_step(self, step: int) -> None:
        """Stamp training progress (called per batch by the injector) — the
        overlap metric is how many of these land during one refresh."""
        self._observed_step = max(self._observed_step, int(step))

    def staleness(self, step: int) -> int:
        """Optimizer updates the served table lags behind ``step`` (one huge
        sentinel before the first refresh lands)."""
        t = self.buffer.read()
        return int(step) - t.step if t.step >= 0 else int(step) + 1

    # --------------------------------------------------------- trainer hook
    def refresh_hook(self, state: Any, step: int) -> Dict[str, float]:
        """PeriodicHook-compatible entry point: the hook's ``every`` is the
        refresh cadence; metrics land in the history row under the hook
        prefix. ``state`` is the train state (``.params``) or a bare param
        pytree."""
        params = getattr(state, "params", state)
        if self.cfg.sync:
            self.refresh(params, step)
        else:
            self.refresh_async(params, step)
        t = self.buffer.read()
        stale = self.staleness(step)
        out = {
            "table_version": float(t.version),
            "table_staleness": float(stale),
            "refreshes": float(self.refreshes),
            "skipped": float(self.skipped),
            "steps_overlapped": float(self.last_overlap),
        }
        if self.cfg.staleness_budget:
            out["stale"] = float(stale > self.cfg.staleness_budget)
        return out

    # ----------------------------------------------------- checkpoint state
    def state_to_save(self) -> Dict[str, np.ndarray]:
        """Fixed-structure np pytree for the checkpoint payload: the
        *published* table only. An in-flight refresh is deliberately not
        captured — on restore it simply re-runs at the next cadence."""
        t = self.buffer.read()
        return {
            "ids": np.asarray(t.ids),
            "meta": np.asarray([t.step, t.version], np.int64),
        }

    def load_saved_state(self, tree: Dict[str, np.ndarray]) -> None:
        """Restore a saved table (drains any in-flight refresh first — it
        was mined for a timeline the restore just rewound)."""
        self.wait()
        meta = np.asarray(tree["meta"])
        self.buffer.swap(
            NegativeTable(
                ids=np.asarray(tree["ids"], np.int32),
                step=int(meta[0]),
                version=int(meta[1]),
            )
        )

    def close(self) -> None:
        """Join the worker without re-raising (shutdown path)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._exc = None
