"""MinerConfig: every knob of the mining subsystem in one frozen dataclass.

Mirrors the ContrastiveConfig / RetrieverConfig pattern: the config is the
single source of truth, validated at construction time of the miner, and the
serving-stack axes (search backend, index layout, precision, encode batch)
pass straight through to the ``RetrieverConfig`` the miner builds — mining
runs on exactly the same dense/fused search programs as serving.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.retrieval.retriever import RetrieverConfig


@dataclasses.dataclass(frozen=True)
class MinerConfig:
    """Hard-negative mining knobs.

    refresh_every: trainer steps between table refreshes (the cadence the
        trainer's PeriodicHook fires the miner at).
    top_k: mining search depth per query — how far down the ranked list the
        teleportation band may reach (must cover ``depth_hi``).
    n_negatives: mined ids published per query (the extra ``passage_hard``
        columns each batch gains).
    staleness_budget: max steps the served table may lag the refresh that
        built it before the refresh hook reports ``stale=1`` (0 disables the
        check). Advisory — async mining is *expected* to serve slightly
        stale negatives; the budget makes "too stale" observable.
    depth_lo/depth_hi: the teleportation band (Sun et al. 2022): negatives
        are taken from gold-excluded ranks ``[depth_lo, depth_hi)`` of the
        retrieved list. Skipping the very top ranks keeps mined negatives
        inside a trust region (rank-0 "negatives" under a fresh model are
        disproportionately unlabeled positives) and avoids the catastrophic
        forgetting naive hardest-first refresh causes.
    margin: score-margin filter on top of the band — candidates scoring
        within ``margin`` of the gold passage (or of the top score when gold
        was not retrieved) are dropped as likely false negatives. 0.0 still
        drops candidates that *outscore* gold.
    sync: run refreshes synchronously on the caller's thread (deterministic
        tests / benchmarking the blocking cost). Default False: refreshes
        run on a background thread against a param snapshot while training
        steps continue.
    query_batch: mining-search query batch (one compiled shape; the tail
        chunk is padded).
    search_impl/index_layout/precision/index_dtype/encode_batch/dp_axis:
        passthrough to the miner's ``RetrieverConfig`` — same semantics as
        serving (retrieval/retriever.py).
    """

    refresh_every: int = 100
    top_k: int = 32
    n_negatives: int = 4
    staleness_budget: int = 0
    depth_lo: int = 1
    depth_hi: int = 32
    margin: float = 0.0
    sync: bool = False
    query_batch: int = 256
    # RetrieverConfig passthrough ------------------------------------------
    search_impl: str = "dense"
    index_layout: str = "replicated"
    precision: Any = "fp32"
    index_dtype: Any = None
    encode_batch: int = 256
    dp_axis: str = "data"

    def validate(self) -> None:
        if self.refresh_every < 1:
            raise ValueError(f"refresh_every must be >= 1 (got {self.refresh_every})")
        if not 0 <= self.depth_lo < self.depth_hi:
            raise ValueError(
                f"teleportation band needs 0 <= depth_lo < depth_hi "
                f"(got [{self.depth_lo}, {self.depth_hi}))"
            )
        if self.top_k < self.depth_hi:
            raise ValueError(
                f"top_k={self.top_k} cannot cover the teleportation band "
                f"[{self.depth_lo}, {self.depth_hi}) — mine at least depth_hi deep"
            )
        if not 1 <= self.n_negatives <= self.depth_hi - self.depth_lo:
            raise ValueError(
                f"n_negatives={self.n_negatives} must fit the band "
                f"[{self.depth_lo}, {self.depth_hi}) "
                f"(width {self.depth_hi - self.depth_lo})"
            )
        if self.margin < 0:
            raise ValueError(f"margin must be >= 0 (got {self.margin})")
        if self.staleness_budget < 0:
            raise ValueError(
                f"staleness_budget must be >= 0 (got {self.staleness_budget})"
            )
        if self.query_batch < 1:
            raise ValueError(f"query_batch must be >= 1 (got {self.query_batch})")

    def retriever_config(self) -> RetrieverConfig:
        """The serving config mining runs on (validated by the Retriever)."""
        return RetrieverConfig(
            top_k=self.top_k,
            search_impl=self.search_impl,
            index_layout=self.index_layout,
            precision=self.precision,
            index_dtype=self.index_dtype,
            encode_batch=self.encode_batch,
            dp_axis=self.dp_axis,
        )
