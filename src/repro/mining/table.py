"""The double-buffered negative table.

The mining refresh and batch assembly run on different threads; the contract
between them is deliberately tiny:

  * a ``NegativeTable`` is an *immutable snapshot* — per-query id rows plus
    the staleness stamp (the training step whose params mined it) and a
    monotonic version. The miner builds a complete new table off to the
    side (the second buffer) and never mutates a published one.
  * ``NegativeTableBuffer`` publishes a finished table with one Python
    reference assignment — atomic under the GIL — so a reader either sees
    the whole old table or the whole new one, never a half-written row, and
    never blocks on an in-flight refresh.

Readers (the loader's ``MinedNegativeInjector``) grab the reference once per
batch and index it; the miner's worker thread swaps whenever a refresh
completes.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class NegativeTable:
    """One published mining result.

    ids: (n_queries, n_negatives) int32 global passage ids; -1 = empty slot
        (band under-filled, or the table predates the first refresh).
    step: training step whose param snapshot mined this table (-1 for the
        initial empty table) — the staleness stamp: ``current_step - step``
        is how many optimizer updates the negatives lag behind.
    version: monotonic refresh counter (0 = initial empty table).
    """

    ids: np.ndarray
    step: int = -1
    version: int = 0

    def __post_init__(self):
        ids = np.asarray(self.ids, np.int32)
        if ids.ndim != 2:
            raise ValueError(f"table ids must be (n_queries, n_negatives), got {ids.shape}")
        ids.setflags(write=False)  # published tables are immutable snapshots
        object.__setattr__(self, "ids", ids)

    @property
    def n_queries(self) -> int:
        return self.ids.shape[0]

    @property
    def n_negatives(self) -> int:
        return self.ids.shape[1]


def empty_table(n_queries: int, n_negatives: int) -> NegativeTable:
    """The pre-first-refresh table: every slot empty (-1), stamp -1."""
    return NegativeTable(
        ids=np.full((n_queries, n_negatives), -1, np.int32), step=-1, version=0
    )


class NegativeTableBuffer:
    """Atomic-swap publication point between the miner and the loader."""

    def __init__(self, table: NegativeTable):
        self._table = table

    def read(self) -> NegativeTable:
        """The current table — one reference read; index the result, don't
        re-read mid-batch (two reads may straddle a swap)."""
        return self._table

    def swap(self, table: NegativeTable) -> NegativeTable:
        """Publish ``table``; returns the table it replaced. Shape must be
        stable — readers bake the column count into batch shapes."""
        old = self._table
        if table.ids.shape != old.ids.shape:
            raise ValueError(
                f"table shape changed across swap: {old.ids.shape} -> "
                f"{table.ids.shape}; readers assume a stable layout"
            )
        self._table = table
        return old
