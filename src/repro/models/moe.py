"""Mixture-of-Experts FFN (token-choice top-k routing, capacity dispatch).

TPU-native formulation (GShard/Switch style, as used by MaxText's "dropping"
strategy): tokens are processed in groups; within a group a k-hot dispatch
tensor (group, experts, capacity) routes tokens into expert buffers via a
single einsum, the experts run as one batched matmul over the expert dim
(sharded over the "model" mesh axis -> expert parallelism; XLA inserts the
all-to-alls), and a combine einsum returns weighted expert outputs.

The group scan bounds the dispatch tensor's memory to
group_size * n_experts * capacity while keeping the expert GEMMs large.
Dispatch-einsum FLOPs scale with group_size (smaller groups = less overhead),
which is one of the §Perf hillclimb levers.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import swiglu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # expert hidden (a.k.a. moe_intermediate)
    capacity_factor: float = 1.25
    group_size: int = 1024         # tokens per dispatch group
    router_aux_weight: float = 0.01
    normalize_top_k: bool = True   # qwen3/mixtral-style renormalization
    # §Perf iteration C1: process all groups as one batched einsum (group dim
    # inherits the token/batch sharding -> groups run data-parallel) instead
    # of a sequential lax.scan over GLOBAL groups, which made every device
    # execute every group on its 1/dp token slice with a partial-sum
    # all-reduce per iteration (measured 1.3 TiB wire/step on qwen3-moe).
    # scan mode remains for memory-constrained single-host debugging.
    vectorize_groups: bool = True


def init_moe(rng, d_model: int, cfg: MoEConfig, n_layers: int, param_dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    e, f = cfg.n_experts, cfg.d_expert

    def stack(key, shape, scale_dim):
        return (
            jax.random.normal(key, (n_layers,) + shape) * (scale_dim ** -0.5)
        ).astype(param_dtype)

    return {
        "router": stack(ks[0], (d_model, e), d_model),
        "w_gate": stack(ks[1], (e, d_model, f), d_model),
        "w_up": stack(ks[2], (e, d_model, f), d_model),
        "w_down": stack(ks[3], (e, f, d_model), f),
    }


def _capacity(group_size: int, cfg: MoEConfig) -> int:
    c = int(group_size * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, cfg.top_k)


def moe_ffn(params, x: jnp.ndarray, cfg: MoEConfig) -> Tuple[jnp.ndarray, dict]:
    """x: (T, d) flattened tokens -> (T, d), plus aux metrics/losses.

    params leaves are per-layer (no leading L dim) — the layer scan slices.
    """
    t, d = x.shape
    g = min(cfg.group_size, t)
    assert t % g == 0, f"token count {t} not divisible by group size {g}"
    n_groups = t // g
    cap = _capacity(g, cfg)
    e = cfg.n_experts

    router = params["router"].astype(jnp.float32)

    def group_step(carry, xg):
        # xg: (g, d)
        logits = xg.astype(jnp.float32) @ router                    # (g, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, cfg.top_k)              # (g, k)
        if cfg.normalize_top_k:
            top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        # k-hot expert mask with gate values at chosen entries
        khot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)          # (g, k, E)
        gates = (khot * top_p[..., None]).sum(1)                    # (g, E)
        mask = khot.sum(1)                                          # (g, E) 0/1

        # position of each token within its expert's capacity buffer
        pos = jnp.cumsum(mask, axis=0) - 1.0                        # (g, E)
        keep = mask * (pos < cap)
        disp = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=x.dtype)
        disp = disp * keep[..., None].astype(x.dtype)               # (g, E, C)
        combine = disp * gates[..., None].astype(x.dtype)           # (g, E, C)

        # dispatch -> expert GEMMs -> combine
        xe = jnp.einsum("gec,gd->ecd", disp, xg)
        h = swiglu(
            jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(xg.dtype)),
            jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(xg.dtype)),
        )
        ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(xg.dtype))
        yg = jnp.einsum("gec,ecd->gd", combine, ye)                 # (g, d)

        # Switch load-balance loss terms: fraction routed vs mean router prob
        f_e = mask.mean(0)          # (E,) fraction of tokens to each expert
        p_e = probs.mean(0)
        aux = e * jnp.sum(f_e * p_e)
        dropped = 1.0 - keep.sum() / jnp.maximum(mask.sum(), 1.0)
        return carry, (yg, aux, dropped)

    if n_groups == 1:
        _, (y, aux, dropped) = group_step(None, x)
        out = y
        aux_mean = aux
        drop_mean = dropped
    elif cfg.vectorize_groups:
        out, aux_mean, drop_mean = _moe_groups_batched(params, x, cfg, n_groups, g, cap)
    else:
        xs = x.reshape(n_groups, g, d)
        _, (ys, auxs, drops) = jax.lax.scan(group_step, None, xs)
        out = ys.reshape(t, d)
        aux_mean = auxs.mean()
        drop_mean = drops.mean()

    metrics = {
        "moe_aux_loss": cfg.router_aux_weight * aux_mean,
        "moe_dropped_frac": drop_mean,
    }
    return out, metrics


def _moe_groups_batched(params, x: jnp.ndarray, cfg: MoEConfig, n_groups: int,
                        g: int, cap: int):
    """All dispatch groups as one batched einsum chain (leading G dim).

    Under GSPMD the G dim inherits the token sharding, so groups execute
    data-parallel; the expert dim stays sharded over "model" (EP). Dispatch
    memory is bounded per device by (G/dp) * g * E * C — the same bound the
    scan enforced globally, now enforced by the sharding.
    """
    t, d = x.shape
    e = cfg.n_experts
    router = params["router"].astype(jnp.float32)
    xs = x.reshape(n_groups, g, d)

    logits = jnp.einsum("Ggd,de->Gge", xs.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)                # (G, g, k)
    if cfg.normalize_top_k:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    khot = jax.nn.one_hot(top_i, e, dtype=jnp.float32)            # (G, g, k, E)
    gates = (khot * top_p[..., None]).sum(2)                      # (G, g, E)
    mask = khot.sum(2)                                            # (G, g, E)

    pos = jnp.cumsum(mask, axis=1) - 1.0                          # (G, g, E)
    keep = mask * (pos < cap)
    disp = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=x.dtype)
    disp = disp * keep[..., None].astype(x.dtype)                 # (G, g, E, C)
    combine = disp * gates[..., None].astype(x.dtype)

    xe = jnp.einsum("Ggec,Ggd->Gecd", disp, xs)
    h = swiglu(
        jnp.einsum("Gecd,edf->Gecf", xe, params["w_gate"].astype(x.dtype)),
        jnp.einsum("Gecd,edf->Gecf", xe, params["w_up"].astype(x.dtype)),
    )
    ye = jnp.einsum("Gecf,efd->Gecd", h, params["w_down"].astype(x.dtype))
    y = jnp.einsum("Ggec,Gecd->Ggd", combine, ye)                 # (G, g, d)

    f_e = mask.mean(1)                                            # (G, E)
    p_e = probs.mean(1)
    aux = (e * jnp.sum(f_e * p_e, axis=-1)).mean()
    dropped = 1.0 - keep.sum() / jnp.maximum(mask.sum(), 1.0)
    return y.reshape(t, d), aux, dropped
