"""Functional model zoo.

Every model is a pair of pure functions:
  ``init(rng, cfg) -> params``  (plain nested dicts of jnp arrays)
  ``apply(params, cfg, ...) -> outputs``

Sharding metadata is *path-based*: distribution/sharding.py maps parameter
path regexes to logical axes, so models stay sharding-agnostic.
"""
