"""Recsys ranking models: DLRM (dot interaction), DCN-v2 (cross network),
DeepFM (factorization machine branch).

The embedding lookup is the hot path. JAX has no native EmbeddingBag, so we
build it: all per-field tables are stacked into ONE row-sharded table with
per-field row offsets ("table stacking" — the standard TPU DLRM layout), and
lookup is `jnp.take` + optional `segment_sum` for multi-hot bags. Under GSPMD
the row-sharded gather lowers to local-gather + mask + all-reduce over the
"model" axis; the §Perf hillclimb iterates on this collective.

A factorized two-tower scoring path (`score_candidates`) serves the
``retrieval_cand`` shape: the user side is computed once and 1M candidate
items are scored with a batched interaction + top-MLP, not 1M full forwards.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_dense: int
    vocab_sizes: Tuple[int, ...]          # rows per sparse field
    embed_dim: int
    interaction: str                      # "dot" | "cross" | "fm"
    bot_mlp: Tuple[int, ...] = ()         # dense-feature tower (DLRM)
    top_mlp: Tuple[int, ...] = ()         # final tower (ends in 1 logit)
    n_cross_layers: int = 0               # DCN-v2
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    # Optional NamedSharding for the (B, F, D) lookup output. Forcing the
    # batch sharding here lets GSPMD lower the row-sharded-table gather to
    # reduce-scatter (+local slice) instead of full-width all-reduce
    # (§Perf iteration A2 — measured: GSPMD ignores it; superseded by A3).
    lookup_sharding: Any = None
    # Optional explicit-collective lookup (table, flat_idx) -> (B, F, D),
    # built by make_psum_scatter_lookup (§Perf iteration A3).
    lookup_fn: Any = None

    # stacked-table rows are padded so the row dim divides the 256-way
    # ("model","data") sharding on both production meshes; padding rows are
    # never indexed (offsets keep per-field ranges disjoint).
    row_pad_multiple: int = 512

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def total_rows(self) -> int:
        raw = int(sum(self.vocab_sizes))
        m = self.row_pad_multiple
        return ((raw + m - 1) // m) * m if m else raw

    def field_offsets(self) -> jnp.ndarray:
        import numpy as np

        return jnp.asarray(
            np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]), jnp.int32
        )

    def param_count(self) -> int:
        n = self.total_rows * self.embed_dim
        dims_in = self._concat_dim()
        for mlp, d0 in ((self.bot_mlp, self.n_dense), (self.top_mlp, dims_in)):
            prev = d0
            for d in mlp:
                n += prev * d + d
                prev = d
        if self.interaction == "cross":
            x0 = self.n_dense + self.n_sparse * self.embed_dim
            n += self.n_cross_layers * (x0 * x0 + x0)
        return n

    def _concat_dim(self) -> int:
        """Input width of the top MLP."""
        f, d = self.n_sparse, self.embed_dim
        if self.interaction == "dot":
            n_items = f + 1  # embeddings + bottom-MLP output
            return (n_items * (n_items - 1)) // 2 + (self.bot_mlp[-1] if self.bot_mlp else 0)
        if self.interaction == "cross":
            x0 = self.n_dense + f * d
            return x0 + (self.top_mlp[-1] if self.top_mlp else 0)  # cross ++ deep
        if self.interaction == "fm":
            return f * d
        raise ValueError(self.interaction)


def _mlp_init(rng, dims: Sequence[int], d_in: int, pd):
    ks = jax.random.split(rng, max(len(dims), 1))
    layers = []
    prev = d_in
    for k, d in zip(ks, dims):
        layers.append({"w": dense_init(k, prev, d, dtype=pd), "b": jnp.zeros((d,), pd)})
        prev = d
    return layers


def _mlp_apply(layers, x, *, final_relu: bool = False):
    for i, lp in enumerate(layers):
        x = x @ lp["w"] + lp["b"]
        if i < len(layers) - 1 or final_relu:
            x = jax.nn.relu(x)
    return x


def init_recsys(rng, cfg: RecsysConfig):
    ks = jax.random.split(rng, 8)
    pd = cfg.param_dtype
    params = {
        # ONE stacked table; sharding rules split it by rows over "model"
        "table": (
            jax.random.uniform(
                ks[0], (cfg.total_rows, cfg.embed_dim), minval=-0.05, maxval=0.05
            )
        ).astype(pd),
    }
    if cfg.bot_mlp:
        params["bot"] = _mlp_init(ks[1], cfg.bot_mlp, cfg.n_dense, pd)
    if cfg.interaction == "cross":
        x0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
        kk = jax.random.split(ks[2], cfg.n_cross_layers)
        params["cross"] = [
            {"w": dense_init(k, x0, x0, scale=0.1 / x0 ** 0.5, dtype=pd), "b": jnp.zeros((x0,), pd)}
            for k in kk
        ]
        params["deep"] = _mlp_init(ks[3], cfg.top_mlp, x0, pd)
        params["final"] = {
            "w": dense_init(ks[4], x0 + cfg.top_mlp[-1], 1, dtype=pd),
            "b": jnp.zeros((1,), pd),
        }
    elif cfg.interaction == "fm":
        params["w_first"] = (jax.random.normal(ks[2], (cfg.total_rows,)) * 0.01).astype(pd)
        params["deep"] = _mlp_init(
            ks[3], tuple(cfg.top_mlp) + (1,), cfg.n_sparse * cfg.embed_dim, pd
        )
    else:  # dot
        params["top"] = _mlp_init(ks[3], cfg.top_mlp, cfg._concat_dim(), pd)
    return params


def embedding_lookup(params, cfg: RecsysConfig, sparse_idx: jnp.ndarray) -> jnp.ndarray:
    """(B, n_sparse) per-field indices -> (B, n_sparse, embed_dim).

    Indices are per-field local; the stacked-table offset is added here.
    """
    flat = sparse_idx + cfg.field_offsets()[None, :]
    if cfg.lookup_fn is not None:
        return cfg.lookup_fn(params["table"], flat).astype(cfg.dtype)
    out = jnp.take(params["table"], flat, axis=0).astype(cfg.dtype)
    if cfg.lookup_sharding is not None:
        out = jax.lax.with_sharding_constraint(out, cfg.lookup_sharding)
    return out


def make_psum_scatter_lookup(mesh, table_axes=("model", "data"),
                             batch_axes=None):
    """Explicit-collective embedding lookup (§Perf iteration A3).

    GSPMD lowers ``jnp.take`` from a row-sharded table to a FULL-WIDTH
    partial + all-reduce + slice (measured on dlrm-mlperf; the constraint
    trick of A2 did not change it). This shard_map formulation does the
    communication-optimal thing by hand:

        all-gather the local indices over the table axes   (KBs)
        masked gather from the local row shard             (local)
        psum_scatter back to the batch sharding            (1/2 the
                                                            all-reduce wire,
                                                            no follow-up
                                                            all-gather)

    Batch must be sharded over ``batch_axes`` (default: pod? + table_axes
    reversed to ("data","model") order) with any "pod" axis outermost; the
    table is replicated across pods, so each pod resolves its own batch
    share independently. Fully differentiable (gather/scatter transposes).

    Returns ``lookup(table, flat_idx) -> (b_local..., F, D)-global-view``.
    """
    from jax.sharding import PartitionSpec as P

    in_pod = tuple(a for a in mesh.axis_names if a in table_axes)
    # batch dim0 ordering: mesh axis order ("pod","data","model")
    if batch_axes is None:
        batch_axes = tuple(mesh.axis_names)
    n_shards = 1
    for a in table_axes:
        n_shards *= mesh.shape[a]
    # gather/scatter axis tuple in the BATCH's dim-0 shard order (mesh order)
    gs_axes = tuple(a for a in batch_axes if a in table_axes)

    def kernel(table_shard, idx_local):
        # table_shard: (rows/n_shards, D); idx_local: (b/dev, F) global row ids
        rows_local = table_shard.shape[0]
        # table row-block index in table_axes major-to-minor order
        shard_id = 0
        for a in table_axes:
            shard_id = shard_id * mesh.shape[a] + jax.lax.axis_index(a)
        row_lo = shard_id * rows_local

        idx_pod = jax.lax.all_gather(idx_local, gs_axes, axis=0, tiled=True)
        rel = idx_pod - row_lo
        ok = (rel >= 0) & (rel < rows_local)
        part = jnp.where(
            ok[..., None],
            jnp.take(table_shard, jnp.clip(rel, 0, rows_local - 1), axis=0),
            0.0,
        )                                              # (B_pod, F, D) partial
        return jax.lax.psum_scatter(part, gs_axes, scatter_dimension=0,
                                    tiled=True)        # (b/dev, F, D)

    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:  # jax < 0.5 keeps it under experimental
        from jax.experimental.shard_map import shard_map

    return shard_map(
        kernel,
        mesh=mesh,
        in_specs=(P(table_axes, None), P(batch_axes, None)),
        out_specs=P(batch_axes, None, None),
    )


def embedding_bag(params, cfg: RecsysConfig, multi_hot: jnp.ndarray, lengths: jnp.ndarray):
    """Multi-hot bags: (B, F, L) indices + (B, F) valid lengths -> mean-pooled
    (B, F, D). JAX's EmbeddingBag equivalent: gather + masked mean."""
    b, f, l = multi_hot.shape
    flat = multi_hot + cfg.field_offsets()[None, :, None]
    vecs = jnp.take(params["table"], flat, axis=0).astype(cfg.dtype)  # (B,F,L,D)
    mask = (jnp.arange(l)[None, None, :] < lengths[..., None]).astype(cfg.dtype)
    s = (vecs * mask[..., None]).sum(2)
    return s / jnp.maximum(mask.sum(2, keepdims=True)[..., 0][..., None], 1.0)


def _dot_interaction(emb: jnp.ndarray, bot: Optional[jnp.ndarray]) -> jnp.ndarray:
    """DLRM pairwise dots: emb (B, F, D) [+ bot (B, D)] -> (B, n_pairs [+D])."""
    items = emb if bot is None else jnp.concatenate([bot[:, None, :], emb], axis=1)
    b, f, d = items.shape
    sims = jnp.einsum("bfd,bgd->bfg", items, items)
    iu, ju = jnp.triu_indices(f, k=1)
    pairs = sims[:, iu, ju]
    return pairs if bot is None else jnp.concatenate([bot, pairs], axis=-1)


def forward(params, cfg: RecsysConfig, dense: jnp.ndarray, sparse_idx: jnp.ndarray):
    """Returns per-example logits (B,)."""
    emb = embedding_lookup(params, cfg, sparse_idx)        # (B, F, D)
    dense = dense.astype(cfg.dtype)
    if cfg.interaction == "dot":
        bot = _mlp_apply(params["bot"], dense, final_relu=True)
        z = _dot_interaction(emb, bot)
        return _mlp_apply(params["top"], z)[:, 0]
    if cfg.interaction == "cross":
        x0 = jnp.concatenate([dense, emb.reshape(emb.shape[0], -1)], axis=-1)
        x = x0
        for lp in params["cross"]:
            x = x0 * (x @ lp["w"] + lp["b"]) + x
        deep = _mlp_apply(params["deep"], x0, final_relu=True)
        z = jnp.concatenate([x, deep], axis=-1)
        return _mlp_apply([params["final"]], z)[:, 0]
    if cfg.interaction == "fm":
        flat_idx = sparse_idx + cfg.field_offsets()[None, :]
        first = jnp.take(params["w_first"], flat_idx, axis=0).sum(-1)
        s = emb.sum(1)
        fm2 = 0.5 * (s * s - (emb * emb).sum(1)).sum(-1)
        deep = _mlp_apply(params["deep"], emb.reshape(emb.shape[0], -1))[:, 0]
        return first + fm2 + deep
    raise ValueError(cfg.interaction)


def bce_loss(params, cfg: RecsysConfig, dense, sparse_idx, labels):
    logits = forward(params, cfg, dense, sparse_idx).astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    acc = jnp.mean(((logits > 0) == (labels > 0.5)).astype(jnp.float32))
    return loss, {"bce": loss, "accuracy": acc}


def score_candidates(
    params,
    cfg: RecsysConfig,
    dense: jnp.ndarray,        # (1, n_dense) one user/query
    sparse_idx: jnp.ndarray,   # (1, n_sparse) user-side fields
    cand_ids: jnp.ndarray,     # (C,) candidate ids for field 0
):
    """retrieval_cand shape: score 1 query against C candidates by swapping
    field 0's embedding. User-side embeddings/bottom tower computed once."""
    emb_user = embedding_lookup(params, cfg, sparse_idx)   # (1, F, D)
    cand = jnp.take(
        params["table"], cand_ids + cfg.field_offsets()[0], axis=0
    ).astype(cfg.dtype)                                     # (C, D)
    c = cand.shape[0]
    emb = jnp.broadcast_to(emb_user, (c,) + emb_user.shape[1:])
    emb = emb.at[:, 0, :].set(cand)
    dense_b = jnp.broadcast_to(dense.astype(cfg.dtype), (c, dense.shape[1]))
    if cfg.interaction == "dot":
        bot = _mlp_apply(params["bot"], dense_b, final_relu=True)
        z = _dot_interaction(emb, bot)
        return _mlp_apply(params["top"], z)[:, 0]
    if cfg.interaction == "cross":
        x0 = jnp.concatenate([dense_b, emb.reshape(c, -1)], axis=-1)
        x = x0
        for lp in params["cross"]:
            x = x0 * (x @ lp["w"] + lp["b"]) + x
        deep = _mlp_apply(params["deep"], x0, final_relu=True)
        z = jnp.concatenate([x, deep], axis=-1)
        return _mlp_apply([params["final"]], z)[:, 0]
    # fm
    flat0 = cand_ids + cfg.field_offsets()[0]
    first_user = jnp.take(
        params["w_first"], sparse_idx[0, 1:] + cfg.field_offsets()[1:], axis=0
    ).sum()
    first = first_user + jnp.take(params["w_first"], flat0, axis=0)
    s = emb.sum(1)
    fm2 = 0.5 * (s * s - (emb * emb).sum(1)).sum(-1)
    deep = _mlp_apply(params["deep"], emb.reshape(c, -1))[:, 0]
    return first + fm2 + deep
