"""Decoder-only causal LM family (covers all five assigned LM architectures).

Modern pre-norm transformer: RMSNorm, RoPE, GQA attention, SwiGLU FFN
(or MoE FFN), optional QKV bias (qwen1.5), untied LM head.

Layers are scanned with stacked parameters so an 80-layer 110B-parameter
model lowers to a compact HLO; remat policy is configurable. The LM loss is
computed in sequence chunks so (B, S, 150k-vocab) logits never materialize.

Three entry points per the assigned shape cells:
  ``train_step_loss``  — causal-LM loss (train_4k)
  ``prefill``          — build KV cache + last-position logits (prefill_32k)
  ``decode_step``      — one new token against a seq_len cache (decode_32k,
                         long_500k; cache seq dim may be sequence-sharded)
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import attention, decode_attention
from repro.models.moe import MoEConfig, init_moe, moe_ffn


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # defaults to d_model // n_heads
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    # execution
    # per-arch declaration: LM towers default to bf16 compute (the presets in
    # configs/ override per size); resolve_precision turns this into a policy
    dtype: Any = jnp.bfloat16  # reprolint: disable=RPL001
    param_dtype: Any = jnp.float32
    attention_impl: str = "chunked"
    q_chunk: int = 512
    kv_chunk: int = 1024
    loss_chunk: int = 512                 # sequence chunk for the xent loss
    remat: str = "full"                   # none | full | dots
    scan_layers: bool = True

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def param_count(self) -> int:
        d, dh = self.d_model, self.dh
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        if self.moe:
            ffn = d * self.moe.n_experts * self.moe.d_expert * 3 + d * self.moe.n_experts
        else:
            ffn = d * self.d_ff * 3
        per_layer = attn + ffn + 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        d, dh = self.d_model, self.dh
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        ffn = d * self.moe.top_k * self.moe.d_expert * 3 + d * self.moe.n_experts
        per_layer = attn + ffn + 2 * d
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d


class KVCache(NamedTuple):
    k: jnp.ndarray        # (L, B, S_max, Hk, Dh)
    v: jnp.ndarray        # (L, B, S_max, Hk, Dh)
    length: jnp.ndarray   # (B,) int32 valid prefix


def init_lm(rng, cfg: LMConfig):
    d, dh, h, hk = cfg.d_model, cfg.dh, cfg.n_heads, cfg.n_kv_heads
    nl = cfg.n_layers
    ks = jax.random.split(rng, 12)
    pd = cfg.param_dtype

    def stack(key, shape, fan_in):
        return (jax.random.normal(key, (nl,) + shape) * (fan_in ** -0.5)).astype(pd)

    attn = {
        "wq": stack(ks[0], (d, h * dh), d),
        "wk": stack(ks[1], (d, hk * dh), d),
        "wv": stack(ks[2], (d, hk * dh), d),
        "wo": stack(ks[3], (h * dh, d), h * dh),
    }
    if cfg.qkv_bias:
        attn["bq"] = jnp.zeros((nl, h * dh), pd)
        attn["bk"] = jnp.zeros((nl, hk * dh), pd)
        attn["bv"] = jnp.zeros((nl, hk * dh), pd)

    if cfg.moe is not None:
        ffn = init_moe(ks[4], d, cfg.moe, nl, pd)
    else:
        ffn = {
            "w_gate": stack(ks[5], (d, cfg.d_ff), d),
            "w_up": stack(ks[6], (d, cfg.d_ff), d),
            "w_down": stack(ks[7], (cfg.d_ff, d), cfg.d_ff),
        }

    params = {
        "embed": (jax.random.normal(ks[8], (cfg.vocab_size, d)) * 0.02).astype(pd),
        "layers": {
            "ln1": jnp.ones((nl, d), pd),
            "ln2": jnp.ones((nl, d), pd),
            "attn": attn,
            "ffn": ffn,
        },
        "final_norm": jnp.ones((d,), pd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(ks[9], (d, cfg.vocab_size)) * (d ** -0.5)
        ).astype(pd)
    return params


def _block(cfg: LMConfig, lp, x, cos, sin, *, kv_mask=None, causal=True):
    """One transformer block. lp: per-layer params (no leading L dim).
    x: (B, S, d). Returns (x', aux_metrics, (k, v))."""
    b, s, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    dt = cfg.dtype

    y = L.rms_norm(lp["ln1"], x, eps=cfg.norm_eps)
    ap = lp["attn"]
    q = y @ ap["wq"].astype(dt)
    k = y @ ap["wk"].astype(dt)
    v = y @ ap["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + ap["bq"].astype(dt)
        k = k + ap["bk"].astype(dt)
        v = v + ap["bv"].astype(dt)
    q = q.reshape(b, s, h, dh)
    k = k.reshape(b, s, hk, dh)
    v = v.reshape(b, s, hk, dh)
    q = L.apply_rotary(q, cos, sin)
    k = L.apply_rotary(k, cos, sin)

    o = attention(
        q, k, v,
        impl=cfg.attention_impl,
        causal=causal,
        kv_mask=kv_mask,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
    )
    x = x + (o.reshape(b, s, h * dh) @ ap["wo"].astype(dt))

    y = L.rms_norm(lp["ln2"], x, eps=cfg.norm_eps)
    aux = {}
    if cfg.moe is not None:
        ff, aux = moe_ffn(lp["ffn"], y.reshape(b * s, d), cfg.moe)
        ff = ff.reshape(b, s, d)
    else:
        fp = lp["ffn"]
        ff = L.swiglu(y @ fp["w_gate"].astype(dt), y @ fp["w_up"].astype(dt)) @ fp[
            "w_down"
        ].astype(dt)
    x = x + ff
    return x, aux, (k, v)


def _remat_wrap(cfg: LMConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {cfg.remat!r}")


def backbone(params, cfg: LMConfig, tokens: jnp.ndarray, *, collect_cache: bool = False):
    """tokens (B, S) -> hidden states (B, S, d) [+ stacked (k, v) per layer]."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    pos = jnp.arange(s)
    cos, sin = L.rotary_embedding(pos, cfg.dh, cfg.rope_theta, cfg.dtype)
    cos = jnp.broadcast_to(cos, (b, s, cfg.dh // 2))
    sin = jnp.broadcast_to(sin, (b, s, cfg.dh // 2))

    moe_aux_acc = jnp.zeros((), jnp.float32)

    def layer_fn(carry, lp):
        x, aux_acc = carry
        x, aux, kv = _block(cfg, lp, x, cos, sin, causal=True)
        aux_acc = aux_acc + aux.get("moe_aux_loss", jnp.zeros((), jnp.float32))
        return (x, aux_acc), (kv if collect_cache else None)

    layer_fn = _remat_wrap(cfg, layer_fn)

    if cfg.scan_layers:
        (x, moe_aux_acc), kvs = jax.lax.scan(layer_fn, (x, moe_aux_acc), params["layers"])
    else:
        kv_list = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            (x, moe_aux_acc), kv = layer_fn((x, moe_aux_acc), lp)
            kv_list.append(kv)
        kvs = (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *kv_list)
            if collect_cache
            else None
        )

    x = L.rms_norm(params["final_norm"], x, eps=cfg.norm_eps)
    return x, moe_aux_acc / cfg.n_layers, kvs


def _head(params, cfg: LMConfig, x):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ w.astype(cfg.dtype)


def lm_loss(params, cfg: LMConfig, tokens: jnp.ndarray, targets: jnp.ndarray):
    """Chunked next-token cross entropy. tokens/targets: (B, S); targets may
    use -1 for padding (masked out). Logits are built loss_chunk columns of
    sequence at a time, so (B, S, V) never materializes."""
    x, moe_aux, _ = backbone(params, cfg, tokens)
    b, s, d = x.shape
    c = min(cfg.loss_chunk, s)
    assert s % c == 0
    n = s // c

    xs = x.reshape(b, n, c, d).transpose(1, 0, 2, 3)
    ts = targets.reshape(b, n, c).transpose(1, 0, 2)

    # checkpoint: without it the scan saves each chunk's (B, c, V) logits for
    # the backward pass — the very tensor the chunking exists to avoid.
    @jax.checkpoint
    def chunk_loss(carry, inp):
        xc, tc = inp
        logits = _head(params, cfg, xc).astype(jnp.float32)   # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tc_safe = jnp.maximum(tc, 0)
        pos = jnp.take_along_axis(logits, tc_safe[..., None], axis=-1)[..., 0]
        mask = (tc >= 0).astype(jnp.float32)
        loss_sum, count = carry
        return (loss_sum + ((lse - pos) * mask).sum(), count + mask.sum()), None

    (loss_sum, count), _ = jax.lax.scan(
        chunk_loss, (jnp.zeros(()), jnp.zeros(())), (xs, ts)
    )
    loss = loss_sum / jnp.maximum(count, 1.0)
    return loss + moe_aux, {"lm_loss": loss, "moe_aux": moe_aux, "tokens": count}


def prefill(params, cfg: LMConfig, tokens: jnp.ndarray, *, max_seq: Optional[int] = None):
    """Build the KV cache for a prompt; returns (cache, last-position logits)."""
    b, s = tokens.shape
    max_seq = max_seq or s
    x, _, kvs = backbone(params, cfg, tokens, collect_cache=True)
    k, v = kvs  # (L, B, S, Hk, Dh)
    if max_seq > s:
        pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    cache = KVCache(
        k=k, v=v, length=jnp.full((b,), s, jnp.int32)
    )
    logits = _head(params, cfg, x[:, -1:, :])[:, 0]
    return cache, logits


def decode_step(params, cfg: LMConfig, cache: KVCache, token: jnp.ndarray):
    """One decode step. token: (B,) int32. Returns (new_cache, logits (B, V)).

    The per-layer attention is a softmax over the cache's sequence dim; when
    that dim is sharded ("model"/"data" axes for the long-context shapes) XLA
    emits partial-softmax + all-reduce (distributed flash-decode).
    """
    b = token.shape[0]
    h, hk, dh, d = cfg.n_heads, cfg.n_kv_heads, cfg.dh, cfg.d_model
    dt = cfg.dtype
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(dt)  # (B, 1, d)
    pos = cache.length  # (B,)
    cos, sin = L.rotary_embedding(pos[:, None], dh, cfg.rope_theta, dt)  # (B, 1, dh/2)

    def layer_fn(carry, inp):
        x, = carry
        lp, kc, vc = inp  # kc/vc: (B, S_max, Hk, Dh)
        y = L.rms_norm(lp["ln1"], x, eps=cfg.norm_eps)
        ap = lp["attn"]
        q = y @ ap["wq"].astype(dt)
        k = y @ ap["wk"].astype(dt)
        v = y @ ap["wv"].astype(dt)
        if cfg.qkv_bias:
            q = q + ap["bq"].astype(dt)
            k = k + ap["bk"].astype(dt)
            v = v + ap["bv"].astype(dt)
        q = L.apply_rotary(q.reshape(b, 1, h, dh), cos, sin)
        k = L.apply_rotary(k.reshape(b, 1, hk, dh), cos, sin)
        v = v.reshape(b, 1, hk, dh)

        # write new kv at position `length` (same for all batch rows here)
        idx = pos[0]
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, idx, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, idx, 0, 0))

        o = decode_attention(q, kc.astype(dt), vc.astype(dt), cache_len=pos + 1)
        x = x + (o.reshape(b, 1, h * dh) @ ap["wo"].astype(dt))

        y = L.rms_norm(lp["ln2"], x, eps=cfg.norm_eps)
        if cfg.moe is not None:
            ff, _ = moe_ffn(lp["ffn"], y.reshape(b, d), cfg.moe)
            ff = ff.reshape(b, 1, d)
        else:
            fp = lp["ffn"]
            ff = L.swiglu(y @ fp["w_gate"].astype(dt), y @ fp["w_up"].astype(dt)) @ fp[
                "w_down"
            ].astype(dt)
        x = x + ff
        return (x,), (kc, vc)

    if cfg.scan_layers:
        (x,), (k_new, v_new) = jax.lax.scan(
            layer_fn, (x,), (params["layers"], cache.k, cache.v)
        )
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            (x,), (kc, vc) = layer_fn((x,), (lp, cache.k[i], cache.v[i]))
            ks.append(kc)
            vs.append(vc)
        k_new, v_new = jnp.stack(ks), jnp.stack(vs)

    x = L.rms_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = _head(params, cfg, x)[:, 0]
    return KVCache(k=k_new, v=v_new, length=cache.length + 1), logits


def encode_pooled(params, cfg: LMConfig, tokens: jnp.ndarray, mask: Optional[jnp.ndarray] = None):
    """LM-as-retriever embedding (GTR/E5 style): mean-pool final hidden states
    over valid positions. Used when the paper's contrastive objective rides on
    a causal-LM backbone."""
    x, _, _ = backbone(params, cfg, tokens)
    if mask is None:
        return x.mean(axis=1)
    m = mask.astype(x.dtype)[..., None]
    return (x * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
