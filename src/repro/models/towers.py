"""Dual-encoder wrappers binding backbones to the core DualEncoder interface.

The paper's retriever is two BERTs ([CLS] pooling); the LM-retriever variant
(GTR/E5 style) wraps a causal-LM backbone with mean pooling. Both produce
``params = {"query": ..., "passage": ...}`` so the core methods' gradient
-norm diagnostics (Fig. 5) apply uniformly.
"""

from __future__ import annotations


import jax

from repro.core.precision import apply_compute_dtype
from repro.core.types import DualEncoder
from repro.models.bert import BertConfig, bert_encode, init_bert
from repro.models.lm import LMConfig, encode_pooled, init_lm


def _as_tokens(batch):
    """Batches may be {'tokens': ..., 'mask': ...} dicts or raw token arrays."""
    if isinstance(batch, dict):
        return batch["tokens"], batch.get("mask")
    return batch, None


def make_bert_dual_encoder(
    cfg: BertConfig, *, shared: bool = False, precision=None
) -> DualEncoder:
    """``precision`` (a PrecisionPolicy or preset name, core/precision.py)
    rebinds the towers' dtypes via ``BertConfig.with_precision``: stored
    params in ``param_dtype`` (fp32 masters), activations and the emitted
    [CLS] representations in ``compute_dtype``. None keeps cfg's dtypes."""
    if precision is not None:
        cfg = cfg.with_precision(precision)

    def init(rng):
        kq, kp = jax.random.split(rng)
        q = init_bert(kq, cfg)
        p = q if shared else init_bert(kp, cfg)
        return {"query": q, "passage": p}

    def encode_query(params, batch):
        tokens, mask = _as_tokens(batch)
        return bert_encode(params["query"], cfg, tokens, mask)

    def encode_passage(params, batch):
        tokens, mask = _as_tokens(batch)
        return bert_encode(params["passage"], cfg, tokens, mask)

    return DualEncoder(
        init=init,
        encode_query=encode_query,
        encode_passage=encode_passage,
        rep_dim=cfg.d_model,
    )


def make_lm_dual_encoder(
    cfg: LMConfig, *, shared: bool = True, precision=None
) -> DualEncoder:
    """LM-as-retriever: one shared causal-LM backbone (the common modern
    setup), mean pooling over valid positions. ``precision`` wraps the
    encoder with the generic compute-dtype caster
    (core/precision.apply_compute_dtype) — LMConfig carries its own dtype,
    so the policy is applied at the DualEncoder boundary."""

    def init(rng):
        kq, kp = jax.random.split(rng)
        q = init_lm(kq, cfg)
        p = q if shared else init_lm(kp, cfg)
        return {"query": q, "passage": p}

    def encode_query(params, batch):
        tokens, mask = _as_tokens(batch)
        return encode_pooled(params["query"], cfg, tokens, mask)

    def encode_passage(params, batch):
        tokens, mask = _as_tokens(batch)
        return encode_pooled(params["passage"], cfg, tokens, mask)

    enc = DualEncoder(
        init=init,
        encode_query=encode_query,
        encode_passage=encode_passage,
        rep_dim=cfg.d_model,
    )
    return enc if precision is None else apply_compute_dtype(enc, precision)
