"""BERT-base encoder — the paper's backbone (bert-base-uncased) for the DPR
dual encoder. Post-LN transformer with learned positional embeddings, GELU
FFN, biases throughout, [CLS] representation (DPR uses the raw final-layer
[CLS], no pooler head)."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.attention import attention


@dataclasses.dataclass(frozen=True)
class BertConfig:
    name: str = "bert-base-uncased"
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    d_ff: int = 3072
    vocab_size: int = 30522
    max_position: int = 512
    type_vocab: int = 2
    norm_eps: float = 1e-12
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    attention_impl: str = "plain"
    remat: str = "none"
    scan_layers: bool = True

    @property
    def dh(self) -> int:
        return self.d_model // self.n_heads

    def with_precision(self, policy) -> "BertConfig":
        """Bind a PrecisionPolicy (core/precision.py; instance or preset
        name): params are *stored* in ``param_dtype`` (fp32 masters in every
        preset) and cast to ``compute_dtype`` at application — ``dtype``
        drives every activation matmul below, and layer_norm keeps its fp32
        internals (models/layers.py), matching the policy's fp32
        ``accum_dtype`` for normalization statistics."""
        import dataclasses as _dc

        from repro.core.precision import resolve_precision

        policy = resolve_precision(policy)
        return _dc.replace(
            self, dtype=policy.compute_dtype, param_dtype=policy.param_dtype
        )

    def param_count(self) -> int:
        d = self.d_model
        per_layer = 4 * d * d + 4 * d + 2 * d * self.d_ff + self.d_ff + d + 4 * d
        emb = (self.vocab_size + self.max_position + self.type_vocab) * d + 2 * d
        return self.n_layers * per_layer + emb


def init_bert(rng, cfg: BertConfig):
    d, nl = cfg.d_model, cfg.n_layers
    ks = jax.random.split(rng, 10)
    pd = cfg.param_dtype

    def stack(key, shape, fan_in):
        return (jax.random.normal(key, (nl,) + shape) * (fan_in ** -0.5)).astype(pd)

    return {
        "embed": {
            "word": (jax.random.normal(ks[0], (cfg.vocab_size, d)) * 0.02).astype(pd),
            "pos": (jax.random.normal(ks[1], (cfg.max_position, d)) * 0.02).astype(pd),
            "type": (jax.random.normal(ks[2], (cfg.type_vocab, d)) * 0.02).astype(pd),
            "ln_s": jnp.ones((d,), pd),
            "ln_b": jnp.zeros((d,), pd),
        },
        "layers": {
            "wqkv": stack(ks[3], (d, 3 * d), d),
            "bqkv": jnp.zeros((nl, 3 * d), pd),
            "wo": stack(ks[4], (d, d), d),
            "bo": jnp.zeros((nl, d), pd),
            "ln1_s": jnp.ones((nl, d), pd),
            "ln1_b": jnp.zeros((nl, d), pd),
            "w1": stack(ks[5], (d, cfg.d_ff), d),
            "b1": jnp.zeros((nl, cfg.d_ff), pd),
            "w2": stack(ks[6], (cfg.d_ff, d), cfg.d_ff),
            "b2": jnp.zeros((nl, d), pd),
            "ln2_s": jnp.ones((nl, d), pd),
            "ln2_b": jnp.zeros((nl, d), pd),
        },
    }


def bert_hidden(params, cfg: BertConfig, tokens: jnp.ndarray, mask: Optional[jnp.ndarray] = None):
    """tokens (B, S) -> final hidden states (B, S, d)."""
    b, s = tokens.shape
    dt = cfg.dtype
    if mask is None:
        mask = jnp.ones((b, s), bool)
    emb = params["embed"]
    x = (
        jnp.take(emb["word"], tokens, axis=0)
        + emb["pos"][None, :s]
        + emb["type"][0][None, None]
    ).astype(dt)
    x = L.layer_norm(emb["ln_s"], emb["ln_b"], x, eps=cfg.norm_eps)

    h, dh, d = cfg.n_heads, cfg.dh, cfg.d_model

    def layer_fn(x, lp):
        qkv = x @ lp["wqkv"].astype(dt) + lp["bqkv"].astype(dt)
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, s, h, dh)
        k = k.reshape(b, s, h, dh)
        v = v.reshape(b, s, h, dh)
        o = attention(q, k, v, impl=cfg.attention_impl, causal=False, kv_mask=mask)
        att = o.reshape(b, s, d) @ lp["wo"].astype(dt) + lp["bo"].astype(dt)
        x = L.layer_norm(lp["ln1_s"], lp["ln1_b"], x + att, eps=cfg.norm_eps)
        ff = L.gelu(x @ lp["w1"].astype(dt) + lp["b1"].astype(dt))
        ff = ff @ lp["w2"].astype(dt) + lp["b2"].astype(dt)
        x = L.layer_norm(lp["ln2_s"], lp["ln2_b"], x + ff, eps=cfg.norm_eps)
        return x, None

    if cfg.remat != "none":
        layer_fn = jax.checkpoint(layer_fn)

    if cfg.scan_layers:
        x, _ = jax.lax.scan(layer_fn, x, params["layers"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, _ = layer_fn(x, lp)
    return x


def bert_encode(params, cfg: BertConfig, tokens: jnp.ndarray, mask: Optional[jnp.ndarray] = None):
    """[CLS] representation, (B, d) — DPR's sentence embedding."""
    return bert_hidden(params, cfg, tokens, mask)[:, 0]
