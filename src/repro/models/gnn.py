"""SchNet (Schütt et al., arXiv:1706.08566) — continuous-filter convolution
GNN, adapted to JAX's segment-op message passing (JAX has no SpMM beyond
BCOO; the gather -> filter -> segment_sum pipeline IS the implementation,
per the kernel taxonomy §GNN).

Graphs are flat edge lists:
  node input:  atomic numbers (molecules) or feature matrix (generic graphs)
  edges:       src (E,), dst (E,) int32, edge_dist (E,) float
  graph_id:    (N,) int32 for graph-level pooling (batched molecules)
  node_mask / edge_mask: padding masks (static shapes everywhere)

Two heads:
  * energy regression (molecule cells): per-atom MLP -> segment_sum by graph
  * node classification (full-graph / sampled cells): linear -> logits
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.precision import STATS_DTYPE
from repro.models.layers import dense_init, shifted_softplus


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    max_z: int = 100                 # atomic-number vocabulary
    d_feat: Optional[int] = None     # generic-graph node features (else atoms)
    n_classes: Optional[int] = None  # node classification head (else energy)
    dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32


class GraphBatch(NamedTuple):
    nodes: jnp.ndarray                 # (N,) int atomic numbers or (N, F) floats
    src: jnp.ndarray                   # (E,) int32 message source
    dst: jnp.ndarray                   # (E,) int32 message target
    edge_dist: jnp.ndarray             # (E,) float
    node_mask: jnp.ndarray             # (N,) bool
    edge_mask: jnp.ndarray             # (E,) bool
    graph_id: Optional[jnp.ndarray] = None   # (N,) int32
    n_graphs: int = 1
    targets: Optional[jnp.ndarray] = None    # (G,) energies or (N,) labels
    target_mask: Optional[jnp.ndarray] = None  # (N,) train mask for node tasks


def init_schnet(rng, cfg: SchNetConfig):
    h = cfg.d_hidden
    ks = jax.random.split(rng, 12)
    pd = cfg.param_dtype

    def dense(key, i, o):
        return {"w": dense_init(key, i, o, dtype=pd), "b": jnp.zeros((o,), pd)}

    def stack_dense(key, i, o):
        n = cfg.n_interactions
        kk = jax.random.split(key, n)
        return {
            "w": jnp.stack([dense_init(kk[j], i, o, dtype=pd) for j in range(n)]),
            "b": jnp.zeros((n, o), pd),
        }

    params = {
        # input
        "embed": (jax.random.normal(ks[0], (cfg.max_z, h)) * 0.3).astype(pd)
        if cfg.d_feat is None
        else dense(ks[0], cfg.d_feat, h),
        # interaction blocks (stacked for scan)
        "in_lin": stack_dense(ks[1], h, h),
        "filt1": stack_dense(ks[2], cfg.n_rbf, h),
        "filt2": stack_dense(ks[3], h, h),
        "out_lin1": stack_dense(ks[4], h, h),
        "out_lin2": stack_dense(ks[5], h, h),
        # head
        "head1": dense(ks[6], h, h // 2),
        "head2": dense(
            ks[7], h // 2, cfg.n_classes if cfg.n_classes else 1
        ),
    }
    return params


def rbf_expand(dist: jnp.ndarray, cfg: SchNetConfig) -> jnp.ndarray:
    """Gaussian radial basis on [0, cutoff]: (E,) -> (E, n_rbf)."""
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = 1.0 / ((cfg.cutoff / cfg.n_rbf) ** 2)
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def _apply_dense(p, x):
    # params are stored in param_dtype (fp32 masters) and cast to the
    # activation dtype at application — same convention as bert.with_policy.
    # Without the cast, fp32 params promote every bf16 activation back to
    # fp32: the interaction scan then fails (carry dtype mismatch) and bf16
    # compute is silently a no-op everywhere else.
    return x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)


def schnet_node_repr(params, cfg: SchNetConfig, g: GraphBatch) -> jnp.ndarray:
    """(N, d_hidden) node representations after n_interactions blocks."""
    if cfg.d_feat is None:
        x = jnp.take(params["embed"], g.nodes, axis=0)
    else:
        x = _apply_dense(params["embed"], g.nodes.astype(cfg.dtype))
    x = x.astype(cfg.dtype)
    n_nodes = x.shape[0]

    rbf = rbf_expand(g.edge_dist.astype(jnp.float32), cfg).astype(cfg.dtype)
    emask = g.edge_mask.astype(cfg.dtype)[:, None]

    def block(x, lp):
        # continuous-filter convolution
        xj = jnp.take(_apply_dense(lp["in_lin"], x), g.src, axis=0)      # (E, h)
        w = shifted_softplus(_apply_dense(lp["filt1"], rbf))
        w = _apply_dense(lp["filt2"], w)                                  # (E, h)
        msg = xj * w * emask
        # fp32 island: per-node message aggregation sums over node degree —
        # accumulate in fp32 like the attention softmax, identity under fp32
        agg = jax.ops.segment_sum(
            msg.astype(jnp.float32), g.dst, num_segments=n_nodes
        ).astype(x.dtype)                                                 # (N, h)
        y = shifted_softplus(_apply_dense(lp["out_lin1"], agg))
        y = _apply_dense(lp["out_lin2"], y)
        return x + y, None

    lps = {
        k: params[k] for k in ("in_lin", "filt1", "filt2", "out_lin1", "out_lin2")
    }
    x, _ = jax.lax.scan(block, x, lps)
    return x * g.node_mask.astype(x.dtype)[:, None]


def schnet_energy(params, cfg: SchNetConfig, g: GraphBatch) -> jnp.ndarray:
    """Per-graph energy: (G,)."""
    x = schnet_node_repr(params, cfg, g)
    e = shifted_softplus(_apply_dense(params["head1"], x))
    e = _apply_dense(params["head2"], e)[:, 0]                            # (N,)
    e = e * g.node_mask.astype(e.dtype)
    gid = (
        g.graph_id
        if g.graph_id is not None
        else jnp.zeros((e.shape[0],), jnp.int32)
    )
    return jax.ops.segment_sum(e, gid, num_segments=g.n_graphs)


def schnet_node_logits(params, cfg: SchNetConfig, g: GraphBatch) -> jnp.ndarray:
    x = schnet_node_repr(params, cfg, g)
    h = shifted_softplus(_apply_dense(params["head1"], x))
    return _apply_dense(params["head2"], h)                               # (N, C)


def schnet_loss(params, cfg: SchNetConfig, g: GraphBatch):
    """MSE (energy) or masked cross-entropy (node classification)."""
    if cfg.n_classes is None:
        # cast BEFORE the reduction (fp32-stats contract, core/precision.py):
        # with bf16 compute and bf16 targets the squared error and its mean
        # would otherwise reduce in bf16 — the xent branch below always did
        # this; the MSE branch only survived because the shape-cell driver
        # happens to hand fp32 targets in
        pred = schnet_energy(params, cfg, g).astype(STATS_DTYPE)
        loss = jnp.mean((pred - g.targets.astype(STATS_DTYPE)) ** 2)
        return loss, {"mse": loss}
    logits = schnet_node_logits(params, cfg, g).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    pos = jnp.take_along_axis(
        logits, jnp.maximum(g.targets, 0)[:, None], axis=-1, mode="clip"
    )[:, 0]
    mask = (
        g.target_mask if g.target_mask is not None else g.node_mask
    ).astype(jnp.float32)
    n = jnp.maximum(mask.sum(), 1.0)
    loss = jnp.sum((lse - pos) * mask) / n
    acc = jnp.sum((jnp.argmax(logits, -1) == g.targets) * mask) / n
    return loss, {"xent": loss, "accuracy": acc}
