"""Attention implementations: GQA with RoPE, three execution paths.

  * ``plain``   — single einsum pair; used for short sequences and decode.
  * ``chunked`` — online-softmax over KV blocks via lax.scan; bounds the live
                  score tensor to (B, H, q_block, kv_block) so 32k-token
                  prefill fits per-chip HBM. This is the XLA analogue of the
                  Pallas flash kernel and is the path the multi-pod dry-run
                  compiles.
  * ``pallas``  — the TPU flash kernel (kernels/flash_attention), selected by
                  config on real hardware; validated in interpret mode.

Shapes follow (batch, seq, heads, head_dim) throughout ("BSHD").
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, Hk, D) -> (B, S, Hk*n_rep, D) for GQA."""
    if n_rep == 1:
        return k
    b, s, hk, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hk, n_rep, d))
    return k.reshape(b, s, hk * n_rep, d)


def plain_attention(
    q: jnp.ndarray,           # (B, Sq, H, D)
    k: jnp.ndarray,           # (B, Skv, Hk, D)
    v: jnp.ndarray,           # (B, Skv, Hk, D)
    *,
    causal: bool = False,
    q_offset: int | jnp.ndarray = 0,
    kv_mask: Optional[jnp.ndarray] = None,  # (B, Skv) bool
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    hk = k.shape[2]
    k = _repeat_kv(k, h // hk)
    v = _repeat_kv(v, h // hk)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    if causal:
        qi = jnp.arange(sq)[:, None] + q_offset
        ki = jnp.arange(k.shape[1])[None, :]
        logits = jnp.where((ki <= qi)[None, None], logits, NEG_INF)
    if kv_mask is not None:
        logits = jnp.where(kv_mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    kv_mask: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    return_lse: bool = False,
) -> jnp.ndarray:
    """Memory-efficient attention: for each query block, scan KV blocks with a
    running (max, sum-exp, weighted-value) accumulator (online softmax).
    Numerics match plain_attention to fp tolerance (tested).

    NOTE: plain autodiff through this function saves the per-block
    probabilities across the scans — an O(S^2) residual. Training paths must
    use ``flash_chunked_attention`` (custom VJP, blockwise-recomputing
    backward) instead; this forward-only form serves inference and as the
    reference the custom VJP is tested against.
    """
    b, sq, h, d = q.shape
    hk = k.shape[2]
    skv = k.shape[1]
    k = _repeat_kv(k, h // hk)
    v = _repeat_kv(v, h // hk)
    scale = scale if scale is not None else d ** -0.5

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, q_chunk, skv, kv_chunk)
    nq, nk = sq // q_chunk, skv // kv_chunk

    # (nk, B, kv_chunk, H, D)
    ks = k.reshape(b, nk, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)
    if kv_mask is not None:
        ms = kv_mask.reshape(b, nk, kv_chunk).transpose(1, 0, 2)
    else:
        ms = jnp.ones((nk, b, kv_chunk), dtype=bool)

    def q_block(qb, qi0):
        # qb: (B, q_chunk, H, D); returns (B, q_chunk, H, D)
        def kv_step(carry, inp):
            m_run, l_run, acc = carry
            kb, vb, mb, ki0 = inp
            logits = (
                jnp.einsum("bqhd,bkhd->bhqk", qb, kb, preferred_element_type=jnp.float32)
                * scale
            )
            if causal:
                qi = qi0 + jnp.arange(q_chunk)[:, None]
                ki = ki0 + jnp.arange(kv_chunk)[None, :]
                logits = jnp.where((ki <= qi)[None, None], logits, NEG_INF)
            logits = jnp.where(mb[:, None, None, :], logits, NEG_INF)
            m_new = jnp.maximum(m_run, logits.max(-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, d), jnp.float32)
        ki0s = jnp.arange(nk) * kv_chunk
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, ms, ki0s))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        lse = m_f + jnp.log(jnp.maximum(l_f, 1e-30))       # (B, H, q_chunk)
        # (B, q_chunk, H, D), (B, q_chunk, H)
        return out.transpose(0, 2, 1, 3).astype(q.dtype), lse.transpose(0, 2, 1)

    if nq == 1:
        out, lse = q_block(q, jnp.asarray(0))
        return (out, lse) if return_lse else out

    qs = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    qi0s = jnp.arange(nq) * q_chunk
    outs, lses = jax.lax.map(lambda args: q_block(*args), (qs, qi0s))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)
    if not return_lse:
        return out
    lse = lses.transpose(1, 0, 2, 3).reshape(b, sq, h)
    return out, lse


# ---------------------------------------------------------------------------
# Flash-style training attention: blockwise-recomputing custom VJP.
#
# Plain autodiff of ``chunked_attention`` stashes each (q_block x kv_block)
# probability tile across the scans — an O(S^2) residual per layer that blows
# the per-chip HBM budget at 4k+ context (measured: 20 GiB of temps for
# internlm2 train_4k). The custom backward recomputes tiles from (q, k, v,
# out, lse) exactly like the Pallas flash kernel's dq/dk/dv passes, so the
# residual is O(S * D).
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_chunked_attention(
    q, k, v, causal: bool = False, scale: Optional[float] = None,
    q_chunk: int = 512, kv_chunk: int = 1024,
):
    """GQA attention with flash memory profile in BOTH directions.
    kv_mask is not supported here (training paths are causal/unmasked);
    masked inference uses ``chunked_attention`` directly."""
    return chunked_attention(
        q, k, v, causal=causal, scale=scale,
        q_chunk=q_chunk, kv_chunk=kv_chunk,
    )


def _flash_fwd(q, k, v, causal, scale, q_chunk, kv_chunk):
    out, lse = chunked_attention(
        q, k, v, causal=causal, scale=scale,
        q_chunk=q_chunk, kv_chunk=kv_chunk, return_lse=True,
    )
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, scale, q_chunk, kv_chunk, res, g):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    hk = k.shape[2]
    n_rep = h // hk
    skv = k.shape[1]
    sc = scale if scale is not None else d ** -0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq, nk = sq // q_chunk, skv // kv_chunk

    kr = _repeat_kv(k, n_rep)
    vr = _repeat_kv(v, n_rep)

    g = g.astype(jnp.float32)
    delta = jnp.einsum("bqhd,bqhd->bqh", g, out.astype(jnp.float32))  # (B,Sq,H)

    # ---- pass 1: dq (outer map over q blocks, scan over kv blocks) ----
    qs = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    gs = g.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    ds_ = delta.reshape(b, nq, q_chunk, h).transpose(1, 0, 2, 3)
    ls_ = lse.reshape(b, nq, q_chunk, h).transpose(1, 0, 2, 3)
    ks_ = kr.reshape(b, nk, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)
    vs_ = vr.reshape(b, nk, kv_chunk, h, d).transpose(1, 0, 2, 3, 4)

    def dq_block(args):
        qi, gi, di, li, qi0 = args

        def kv_step(dq_acc, inp):
            ki, vi, ki0 = inp
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qi, ki, preferred_element_type=jnp.float32
            ) * sc
            if causal:
                rows = qi0 + jnp.arange(q_chunk)[:, None]
                cols = ki0 + jnp.arange(kv_chunk)[None, :]
                s = jnp.where((cols <= rows)[None, None], s, NEG_INF)
            p = jnp.exp(s - li.transpose(0, 2, 1)[..., None])
            dp = jnp.einsum(
                "bqhd,bkhd->bhqk", gi, vi, preferred_element_type=jnp.float32
            )
            ds = p * (dp - di.transpose(0, 2, 1)[..., None]) * sc
            dq_acc = dq_acc + jnp.einsum(
                "bhqk,bkhd->bqhd", ds, ki.astype(jnp.float32)
            )
            return dq_acc, None

        dq0 = jnp.zeros((b, q_chunk, h, d), jnp.float32)
        ki0s = jnp.arange(nk) * kv_chunk
        dq_i, _ = jax.lax.scan(kv_step, dq0, (ks_, vs_, ki0s))
        return dq_i

    qi0s = jnp.arange(nq) * q_chunk
    dq = jax.lax.map(dq_block, (qs, gs, ds_, ls_, qi0s))
    dq = dq.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d).astype(q.dtype)

    # ---- pass 2: dk, dv (outer map over kv blocks, scan over q blocks) ----
    def dkv_block(args):
        ki, vi, ki0 = args

        def q_step(carry, inp):
            dk_acc, dv_acc = carry
            qi, gi, di, li, qi0 = inp
            s = jnp.einsum(
                "bqhd,bkhd->bhqk", qi, ki, preferred_element_type=jnp.float32
            ) * sc
            if causal:
                rows = qi0 + jnp.arange(q_chunk)[:, None]
                cols = ki0 + jnp.arange(kv_chunk)[None, :]
                s = jnp.where((cols <= rows)[None, None], s, NEG_INF)
            p = jnp.exp(s - li.transpose(0, 2, 1)[..., None])
            dv_acc = dv_acc + jnp.einsum(
                "bhqk,bqhd->bkhd", p, gi
            )
            dp = jnp.einsum(
                "bqhd,bkhd->bhqk", gi, vi, preferred_element_type=jnp.float32
            )
            ds = p * (dp - di.transpose(0, 2, 1)[..., None]) * sc
            dk_acc = dk_acc + jnp.einsum(
                "bhqk,bqhd->bkhd", ds, qi.astype(jnp.float32)
            )
            return (dk_acc, dv_acc), None

        z = jnp.zeros((b, kv_chunk, h, d), jnp.float32)
        (dk_i, dv_i), _ = jax.lax.scan(q_step, (z, z), (qs, gs, ds_, ls_, qi0s))
        return dk_i, dv_i

    ki0s = jnp.arange(nk) * kv_chunk
    dk, dv = jax.lax.map(dkv_block, (ks_, vs_, ki0s))
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(b, skv, h, d)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(b, skv, h, d)
    # GQA: fold the repeated query-head groups back onto the kv heads
    if n_rep > 1:
        dk = dk.reshape(b, skv, hk, n_rep, d).sum(3)
        dv = dv.reshape(b, skv, hk, n_rep, d).sum(3)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


flash_chunked_attention.defvjp(_flash_fwd, _flash_bwd)


def decode_attention(
    q: jnp.ndarray,        # (B, 1, H, D) — one new token
    k_cache: jnp.ndarray,  # (B, S, Hk, D)
    v_cache: jnp.ndarray,  # (B, S, Hk, D)
    *,
    cache_len: jnp.ndarray,  # (B,) or scalar — valid prefix length
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-token decode against a (possibly sequence-sharded) KV cache.
    The softmax over the cache length is a plain reduction, which XLA's SPMD
    partitioner turns into partial-softmax + all-reduce when the cache's
    sequence dim is sharded (context parallelism for the long_500k shape)."""
    b, _, h, d = q.shape
    skv = k_cache.shape[1]
    mask = jnp.arange(skv)[None, :] < jnp.reshape(cache_len, (-1, 1))
    return plain_attention(q, k_cache, v_cache, kv_mask=mask, scale=scale)


def attention(
    q,
    k,
    v,
    *,
    impl: str = "chunked",
    causal: bool = False,
    kv_mask=None,
    scale=None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
):
    if impl == "plain":
        return plain_attention(q, k, v, causal=causal, kv_mask=kv_mask, scale=scale)
    if impl == "chunked":
        if kv_mask is None:
            # differentiable path with flash memory profile in both directions
            return flash_chunked_attention(
                q, k, v, causal, scale, q_chunk, kv_chunk
            )
        return chunked_attention(
            q, k, v, causal=causal, kv_mask=kv_mask, scale=scale,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as flash_ops

        return flash_ops.flash_attention(q, k, v, causal=causal, kv_mask=kv_mask, scale=scale)
    raise ValueError(f"unknown attention impl {impl!r}")
