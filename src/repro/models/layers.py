"""Shared primitive layers (pure functions over explicit param dicts)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def dense_init(rng, d_in: int, d_out: int, *, scale: Optional[float] = None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out)) * scale).astype(dtype)


def linear(params, x, *, bias_key: str = "b", weight_key: str = "w"):
    y = x @ params[weight_key]
    if bias_key in params:
        y = y + params[bias_key]
    return y


def layer_norm(scale, bias, x, *, eps: float = 1e-12):
    """LayerNorm in fp32 (bf16-safe), matching BERT's eps."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def rms_norm(scale, x, *, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu(gate, up):
    return jax.nn.silu(gate) * up


def shifted_softplus(x):
    """SchNet's activation: ln(0.5 e^x + 0.5)."""
    return jax.nn.softplus(x) - math.log(2.0)


def dropout(rng, x, rate: float, deterministic: bool):
    if deterministic or rate == 0.0:
        return x
    keep = jax.random.bernoulli(rng, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def rotary_embedding(positions, head_dim: int, theta: float = 10000.0, dtype=jnp.float32):
    """(..., seq) int positions -> (..., seq, head_dim/2) cos & sin."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, D/2)
    return jnp.cos(angles).astype(dtype), jnp.sin(angles).astype(dtype)


def apply_rotary(x, cos, sin):
    """x: (..., S, H, D). cos/sin: (..., S, D/2) broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
