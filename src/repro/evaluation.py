"""Retrieval evaluation: exact Top@k over a corpus (the paper's metric)."""

from __future__ import annotations

from typing import Dict, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.types import DualEncoder


def encode_corpus(enc: DualEncoder, params, passages: np.ndarray, batch: int = 256):
    reps = []
    for lo in range(0, len(passages), batch):
        reps.append(np.asarray(
            enc.encode_passage(params, jnp.asarray(passages[lo:lo + batch]))
        ))
    return np.concatenate(reps)


def evaluate_topk(
    enc: DualEncoder,
    params,
    corpus,
    ks: Sequence[int] = (1, 5, 20),
) -> Dict[str, float]:
    """Exact retrieval eval over the whole corpus (paper's Top@k): corpus must
    expose ``eval_split() -> (queries, passages, gold_idx)``."""
    queries, passages, gold = corpus.eval_split(
        n=min(256, corpus.n_passages // 4)
    )
    q = np.asarray(enc.encode_query(params, jnp.asarray(queries)))
    p = encode_corpus(enc, params, passages)
    scores = q @ p.T
    order = np.argsort(-scores, axis=1)
    return {
        f"top@{k}": float(np.mean([
            gold[i] in order[i, :k] for i in range(len(gold))
        ]))
        for k in ks
    }
