"""Retrieval evaluation: exact Top@k over a corpus (the paper's metric).

A thin wrapper over the Retriever API (repro/retrieval): the corpus is
encoded into an IndexStore and each eval query's top-max(ks) ids come from
the blocked exact search — the old full (Q, N) score matrix + all-N argsort
is gone, so peak transient memory is bounded by the search backend's block
size instead of the corpus size (pinned by tests/test_retrieval.py).

Because the Retriever is built from the *training* DualEncoder + params, the
same call serves the trainer's periodic eval hook
(``TrainerConfig.eval_every``) — the ANCE-style loop of re-encoding and
searching the corpus with the current training-time encoder.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.types import DualEncoder
from repro.retrieval.index import encode_corpus as _encode_corpus
from repro.retrieval.retriever import Retriever, RetrieverConfig


def encode_corpus(enc: DualEncoder, params, passages: np.ndarray, batch: int = 256):
    """Fixed-batch passage-tower corpus encode (kept for existing callers;
    the Retriever builds its IndexStore through the same path)."""
    import jax

    encode = jax.jit(enc.encode_passage)
    return _encode_corpus(lambda toks: encode(params, toks), passages, batch=batch)


def recall_at(ids: np.ndarray, gold: np.ndarray, ks: Sequence[int]) -> Dict[str, float]:
    """Recall at every cutoff in ``ks`` from one ranked id list
    (Q, >=max(ks)); -1 ids (empty slots) never match. Each cutoff is
    reported twice: ``recall@{k}`` (the canonical name — what mining-quality
    curves plot) and ``top@{k}`` (the historical field, kept for backward
    compat). One search, many cutoffs — no extra encodes."""
    gold = np.asarray(gold)
    out: Dict[str, float] = {}
    for k in ks:
        hit = float(np.mean((ids[:, :k] == gold[:, None]).any(axis=1)))
        out[f"top@{k}"] = hit
        out[f"recall@{k}"] = hit
    return out


def evaluate_topk(
    enc: DualEncoder,
    params,
    corpus,
    ks: Sequence[int] = (1, 5, 20),
    *,
    retriever: Optional[Retriever] = None,
    cfg: Optional[RetrieverConfig] = None,
) -> Dict[str, float]:
    """Exact retrieval eval over the whole corpus (paper's Top@k): corpus must
    expose ``eval_split() -> (queries, passages, gold_idx)``. Every cutoff
    in ``ks`` comes out of the *one* search (k = max(ks), then slicing), as
    both ``recall@{k}`` and legacy ``top@{k}`` keys — pass e.g.
    ``ks=(1, 10, 100)`` for mining-quality curves at no extra encode cost.

    Pass ``retriever`` for periodic eval (the trainer hook): its layout/
    backend/precision and *jitted programs* are reused across calls — the
    retriever's params are refreshed to ``params`` and the corpus is
    re-encoded each call (the ANCE re-encode), so repeated evals pay no
    re-trace. Or pass ``cfg`` to control the search configuration; by
    default a replicated dense fp32 Retriever is built on the fly (one-off
    compile — fine for a single eval, wasteful inside a training loop) —
    results identical to the historical argsort path."""
    queries, passages, gold = corpus.eval_split(
        n=min(256, corpus.n_passages // 4)
    )
    k_max = max(ks)
    if retriever is None:
        cfg = cfg or RetrieverConfig()
        if cfg.top_k < k_max:
            import dataclasses

            cfg = dataclasses.replace(cfg, top_k=k_max)
        retriever = Retriever(enc, params, cfg)
        retriever.build_index(passages)
    else:
        if cfg is not None:
            raise ValueError(
                "pass either retriever= (its own RetrieverConfig is used) "
                "or cfg=, not both — the cfg would be silently ignored"
            )
        if retriever.cfg.top_k < k_max:
            raise ValueError(
                f"retriever.top_k={retriever.cfg.top_k} < max(ks)={k_max}"
            )
        # refresh to the current training-time params and re-encode: a
        # stale index would silently score against an old encoder
        retriever.params = params
        retriever.build_index(passages)
    ids, _ = retriever.search(queries)
    return recall_at(ids, gold, ks)
