"""dlrm-rm2 [recsys]: n_dense=13 n_sparse=26 embed_dim=64 bot=13-512-256-64
top=512-512-256-1 interaction=dot [arXiv:1906.00091]."""

from repro.configs.base import ArchSpec, CRITEO_VOCABS, RECSYS_SHAPES, register
from repro.models.recsys import RecsysConfig

register(
    ArchSpec(
        arch_id="dlrm-rm2",
        family="recsys",
        model_cfg=RecsysConfig(
            name="dlrm-rm2",
            n_dense=13,
            vocab_sizes=CRITEO_VOCABS,
            embed_dim=64,
            interaction="dot",
            bot_mlp=(512, 256, 64),
            top_mlp=(512, 512, 256, 1),
        ),
        shapes=RECSYS_SHAPES,
    )
)
