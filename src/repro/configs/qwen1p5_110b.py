"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064, QKV bias [hf:Qwen/Qwen1.5 family]."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.lm import LMConfig

register(
    ArchSpec(
        arch_id="qwen1.5-110b",
        family="lm",
        model_cfg=LMConfig(
            name="qwen1.5-110b",
            n_layers=80,
            d_model=8192,
            n_heads=64,
            n_kv_heads=8,
            d_ff=49152,
            vocab_size=152064,
            head_dim=128,
            qkv_bias=True,
            rope_theta=1000000.0,
            dtype=jnp.bfloat16,
            remat="full",
        ),
        shapes=LM_SHAPES,
        # 86 GB of layer-boundary activations per device without accumulation;
        # 16 microbatches bound them to ~5.4 GB (see EXPERIMENTS.md §Dry-run)
        micro_batches={"train_4k": 16},
    )
)
