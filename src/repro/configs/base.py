"""Config primitives: ArchSpec (architecture + its shape cells) and the
per-family shape-cell tables from the assignment."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

_REGISTRY: Dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode | gnn_full | gnn_minibatch |
                       # gnn_mol | recsys_train | recsys_serve | recsys_retrieval |
                       # contrastive
    params: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                      # lm | bert | gnn | recsys
    model_cfg: Any
    shapes: Dict[str, ShapeCell]
    micro_batches: Dict[str, int] = dataclasses.field(default_factory=dict)
    notes: str = ""

    def micro_batch(self, shape_name: str) -> int:
        return self.micro_batches.get(shape_name, 1)


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs():
    return sorted(_REGISTRY)


# ------------------------------------------------------------ LM shape cells
LM_SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    "prefill_32k": ShapeCell(
        "prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}
    ),
    "decode_32k": ShapeCell(
        "decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}
    ),
    "long_500k": ShapeCell(
        "long_500k", "decode", {"seq_len": 524288, "global_batch": 1}
    ),
}

# ----------------------------------------------------------- GNN shape cells
GNN_SHAPES: Dict[str, ShapeCell] = {
    "full_graph_sm": ShapeCell(
        "full_graph_sm",
        "gnn_full",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7},
    ),
    "minibatch_lg": ShapeCell(
        "minibatch_lg",
        "gnn_minibatch",
        {
            "n_nodes": 232965,
            "n_edges": 114615892,
            "batch_nodes": 1024,
            "fanouts": (15, 10),
            "d_feat": 602,
            "n_classes": 41,
        },
    ),
    "ogb_products": ShapeCell(
        "ogb_products",
        "gnn_full",
        {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100, "n_classes": 47},
    ),
    "molecule": ShapeCell(
        "molecule",
        "gnn_mol",
        {"n_nodes": 30, "n_edges": 64, "batch": 128},
    ),
}

# -------------------------------------------------------- recsys shape cells
RECSYS_SHAPES: Dict[str, ShapeCell] = {
    "train_batch": ShapeCell("train_batch", "recsys_train", {"batch": 65536}),
    "serve_p99": ShapeCell("serve_p99", "recsys_serve", {"batch": 512}),
    "serve_bulk": ShapeCell("serve_bulk", "recsys_serve", {"batch": 262144}),
    "retrieval_cand": ShapeCell(
        "retrieval_cand", "recsys_retrieval", {"batch": 1, "n_candidates": 1_000_000}
    ),
}

# Criteo-1TB (MLPerf DLRM) per-field embedding cardinalities [arXiv:1906.00091]
CRITEO_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)
