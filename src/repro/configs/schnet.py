"""schnet [gnn]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10
[arXiv:1706.08566]. ContAccum inapplicability noted in DESIGN.md §3."""

from repro.configs.base import ArchSpec, GNN_SHAPES, register
from repro.models.gnn import SchNetConfig

register(
    ArchSpec(
        arch_id="schnet",
        family="gnn",
        model_cfg=SchNetConfig(
            name="schnet", n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0
        ),
        shapes=GNN_SHAPES,
    )
)
