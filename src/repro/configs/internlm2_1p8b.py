"""internlm2-1.8b [dense]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92544 [arXiv:2403.17297]."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.lm import LMConfig

register(
    ArchSpec(
        arch_id="internlm2-1.8b",
        family="lm",
        model_cfg=LMConfig(
            name="internlm2-1.8b",
            n_layers=24,
            d_model=2048,
            n_heads=16,
            n_kv_heads=8,
            d_ff=8192,
            vocab_size=92544,
            head_dim=128,
            rope_theta=1000000.0,
            dtype=jnp.bfloat16,
            remat="full",
        ),
        shapes=LM_SHAPES,
        micro_batches={"train_4k": 4},
    )
)
