"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) vocab=151936,
MoE 128 experts top-8, d_expert=1536 [hf:Qwen/Qwen3 family]."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.lm import LMConfig
from repro.models.moe import MoEConfig

register(
    ArchSpec(
        arch_id="qwen3-moe-235b-a22b",
        family="lm",
        model_cfg=LMConfig(
            name="qwen3-moe-235b-a22b",
            n_layers=94,
            d_model=4096,
            n_heads=64,
            n_kv_heads=4,
            d_ff=0,
            vocab_size=151936,
            head_dim=128,
            rope_theta=1000000.0,
            dtype=jnp.bfloat16,
            remat="full",
            moe=MoEConfig(
                n_experts=128,
                top_k=8,
                d_expert=1536,
                capacity_factor=1.25,
                group_size=1024,
            ),
        ),
        shapes=LM_SHAPES,
        micro_batches={"train_4k": 16},
        notes=(
            "AdamW moments stored bf16 (optim.adamw moment_dtype): 235B fp32 "
            "moments would need 7.3 GB/chip on 256 chips, over the v5e budget "
            "with activations; see EXPERIMENTS.md §Dry-run."
        ),
    )
)
