"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) vocab=50304,
MoE 64 experts top-8, d_expert=1024 [arXiv:2409.02060]."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.lm import LMConfig
from repro.models.moe import MoEConfig

register(
    ArchSpec(
        arch_id="olmoe-1b-7b",
        family="lm",
        model_cfg=LMConfig(
            name="olmoe-1b-7b",
            n_layers=16,
            d_model=2048,
            n_heads=16,
            n_kv_heads=16,
            d_ff=0,
            vocab_size=50304,
            head_dim=128,
            rope_theta=10000.0,
            dtype=jnp.bfloat16,
            remat="full",
            moe=MoEConfig(
                n_experts=64,
                top_k=8,
                d_expert=1024,
                capacity_factor=1.25,
                group_size=1024,
            ),
        ),
        shapes=LM_SHAPES,
        micro_batches={"train_4k": 4},
    )
)
