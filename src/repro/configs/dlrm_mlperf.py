"""dlrm-mlperf [recsys]: MLPerf DLRM benchmark config (Criteo 1TB):
n_dense=13 n_sparse=26 embed_dim=128 bot=13-512-256-128
top=1024-1024-512-256-1 interaction=dot [arXiv:1906.00091]."""

from repro.configs.base import ArchSpec, CRITEO_VOCABS, RECSYS_SHAPES, register
from repro.models.recsys import RecsysConfig

register(
    ArchSpec(
        arch_id="dlrm-mlperf",
        family="recsys",
        model_cfg=RecsysConfig(
            name="dlrm-mlperf",
            n_dense=13,
            vocab_sizes=CRITEO_VOCABS,
            embed_dim=128,
            interaction="dot",
            bot_mlp=(512, 256, 128),
            top_mlp=(1024, 1024, 512, 256, 1),
        ),
        shapes=RECSYS_SHAPES,
    )
)
