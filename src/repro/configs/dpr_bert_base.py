"""dpr-bert-base — the paper's own architecture: two bert-base-uncased
towers trained with ContAccum. Shape cells cover the paper's local/total
batch geometry plus a pod-scale contrastive cell (the framework's flagship:
cross-device negatives + dual memory banks on the production mesh)."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeCell, register
from repro.models.bert import BertConfig

DPR_SHAPES = {
    # the paper's geometry: N_total=128, N_local=8, K=16, N_mem=2048 (NQ)
    "paper_batch": ShapeCell(
        "paper_batch",
        "contrastive",
        {
            "global_batch": 128,
            "accum_steps": 1,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
        },
    ),
    # the paper's geometry on the fused Pallas loss backend: the extended
    # (B + N_mem) logits block streams through VMEM instead of HBM
    "paper_batch_fused": ShapeCell(
        "paper_batch_fused",
        "contrastive",
        {
            "global_batch": 128,
            "accum_steps": 1,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
            "loss_impl": "fused",
        },
    ),
    # the paper's geometry under the full bf16 PrecisionPolicy
    # (core/precision.py 'bf16_banks'): bf16 tower compute + bf16 bank rings
    # (half the persistent bank HBM of paper_batch), fp32 masters and softmax
    # statistics — trajectory within documented tolerance of fp32
    # (tests/test_precision.py)
    "paper_batch_bf16": ShapeCell(
        "paper_batch_bf16",
        "contrastive",
        {
            "global_batch": 128,
            "accum_steps": 1,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
            "precision": "bf16_banks",
        },
    ),
    # the paper's full K=16 accumulation geometry, bf16 banks + the fused
    # Pallas loss backend: the extended logits block streams through VMEM in
    # bf16 tiles, bank rings cost (2*2048*768*2)/1 bytes per device
    "contaccum_bf16": ShapeCell(
        "contaccum_bf16",
        "contrastive",
        {
            "method": "contaccum",
            "global_batch": 128,
            "accum_steps": 16,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
            "precision": "bf16_banks",
            "loss_impl": "fused",
        },
    ),
    # the paper's geometry + asynchronously mined hard negatives
    # (repro/mining): each query carries 8 extra passage columns published
    # by the ANCE-style background refresh — negatives='mined' composes
    # with direct backprop, no banks
    "paper_batch_mined": ShapeCell(
        "paper_batch_mined",
        "contrastive",
        {
            "method": "mined",
            "global_batch": 128,
            "accum_steps": 1,
            "bank_size": 0,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
            "mined_negatives": 8,
        },
    ),
    # the paper's full K=16 ContAccum geometry with mined columns on top:
    # the dual banks keep extending the similarity matrix while every batch
    # also carries 4 globally-mined hard negatives per query — the
    # contaccum x mined composition the mining subsystem exists for
    "contaccum_mined": ShapeCell(
        "contaccum_mined",
        "contrastive",
        {
            "method": "contaccum",
            "global_batch": 128,
            "accum_steps": 16,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
            "mined_negatives": 4,
        },
    ),
    # pod-scale: 16k pairs/step with 32k-deep dual banks
    "contrastive_16k": ShapeCell(
        "contrastive_16k",
        "contrastive",
        {
            "global_batch": 16384,
            "accum_steps": 1,
            "bank_size": 32768,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
        },
    ),
    # new compositions the monolithic API could not express
    # (core/step_program.py): cached-VJP backprop + dual banks ...
    "contcache_batch": ShapeCell(
        "contcache_batch",
        "contrastive",
        {
            "method": "contcache",
            "global_batch": 128,
            "accum_steps": 16,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
        },
    ),
    # explicit shard_map cells: batch AND memory banks sharded over the DP
    # axes (cfg.shard_banks) — persistent bank state shrinks to bank_size/D
    # ring slots per device, and the fused Pallas backend keeps the (M, N)
    # extended logits block out of HBM. The loss still all-gathers the
    # passage-bank columns per evaluation, so a transient (bank_size, d)
    # column block exists per device — budget for it, or pick the
    # *_xdev_ring cell below which streams the shards instead
    "contaccum_xdev": ShapeCell(
        "contaccum_xdev",
        "contrastive",
        {
            "method": "contaccum",
            "global_batch": 2048,
            "accum_steps": 4,
            "bank_size": 8192,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
            "xdev": True,
            "shard_banks": True,
            "loss_impl": "fused",
        },
    ),
    # contaccum_xdev with loss_comm='ring': no transient (bank_size, d)
    # all-gather block — each device streams the D bank shards past its
    # local query rows via ppermute, merging online-softmax stats, so the
    # per-eval transient is O(bank_size*d/D). Exact (not approximate) vs
    # the all-gather cell; trades one all-gather for D-1 ring hops
    "contaccum_xdev_ring": ShapeCell(
        "contaccum_xdev_ring",
        "contrastive",
        {
            "method": "contaccum",
            "global_batch": 2048,
            "accum_steps": 4,
            "bank_size": 8192,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
            "xdev": True,
            "shard_banks": True,
            "loss_impl": "fused",
            "loss_comm": "ring",
        },
    ),
    # full-batch rep-cache backprop + sharded dual banks under shard_map
    "contcache_xdev": ShapeCell(
        "contcache_xdev",
        "contrastive",
        {
            "method": "contcache",
            "global_batch": 2048,
            "accum_steps": 16,
            "bank_size": 8192,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
            "xdev": True,
            "shard_banks": True,
        },
    ),
    # the inference half (repro/retrieval): online serving shape — one
    # coalesced query batch against a 1M-passage index sharded in row
    # blocks over the DP axes, bf16 index rows (policy bank dtype), fp32
    # scores. 6 MiB of index per device on the 256-chip mesh vs 3 GiB
    # replicated fp32
    "serve_topk": ShapeCell(
        "serve_topk",
        "retrieval_serve",
        {
            "n_queries": 32,
            "n_passages": 1 << 20,
            "top_k": 100,
            "q_len": 32,
            "search_impl": "dense",
            "precision": "bf16_banks",
        },
    ),
    # the offline ANCE-style eval sweep: thousands of queries per pass with
    # the training-time encoder, fused Pallas QK^T + running-top-k so the
    # (Q, N) score matrix never materializes
    "eval_topk": ShapeCell(
        "eval_topk",
        "retrieval_eval",
        {
            "n_queries": 2048,
            "n_passages": 1 << 20,
            "top_k": 100,
            "q_len": 32,
            "search_impl": "fused",
            "precision": "bf16_banks",
        },
    ),
    # ... and cached-VJP + passage-only bank (pre-batch negatives)
    "prebatch_cache_batch": ShapeCell(
        "prebatch_cache_batch",
        "contrastive",
        {
            "method": "prebatch_cache",
            "global_batch": 128,
            "accum_steps": 16,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
        },
    ),
}

register(
    ArchSpec(
        arch_id="dpr-bert-base",
        family="bert",
        model_cfg=BertConfig(
            name="bert-base-uncased",
            n_layers=12,
            d_model=768,
            n_heads=12,
            d_ff=3072,
            vocab_size=30522,
            max_position=512,
            dtype=jnp.bfloat16,
            remat="full",
        ),
        shapes=DPR_SHAPES,
    )
)
