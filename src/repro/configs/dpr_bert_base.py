"""dpr-bert-base — the paper's own architecture: two bert-base-uncased
towers trained with ContAccum. Shape cells cover the paper's local/total
batch geometry plus a pod-scale contrastive cell (the framework's flagship:
cross-device negatives + dual memory banks on the production mesh)."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, ShapeCell, register
from repro.models.bert import BertConfig

DPR_SHAPES = {
    # the paper's geometry: N_total=128, N_local=8, K=16, N_mem=2048 (NQ)
    "paper_batch": ShapeCell(
        "paper_batch",
        "contrastive",
        {
            "global_batch": 128,
            "accum_steps": 1,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
        },
    ),
    # the paper's geometry on the fused Pallas loss backend: the extended
    # (B + N_mem) logits block streams through VMEM instead of HBM
    "paper_batch_fused": ShapeCell(
        "paper_batch_fused",
        "contrastive",
        {
            "global_batch": 128,
            "accum_steps": 1,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
            "loss_impl": "fused",
        },
    ),
    # pod-scale: 16k pairs/step with 32k-deep dual banks
    "contrastive_16k": ShapeCell(
        "contrastive_16k",
        "contrastive",
        {
            "global_batch": 16384,
            "accum_steps": 1,
            "bank_size": 32768,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
        },
    ),
    # new compositions the monolithic API could not express
    # (core/step_program.py): cached-VJP backprop + dual banks ...
    "contcache_batch": ShapeCell(
        "contcache_batch",
        "contrastive",
        {
            "method": "contcache",
            "global_batch": 128,
            "accum_steps": 16,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
        },
    ),
    # ... and cached-VJP + passage-only bank (pre-batch negatives)
    "prebatch_cache_batch": ShapeCell(
        "prebatch_cache_batch",
        "contrastive",
        {
            "method": "prebatch_cache",
            "global_batch": 128,
            "accum_steps": 16,
            "bank_size": 2048,
            "q_len": 32,
            "p_len": 256,
            "n_hard": 1,
        },
    ),
}

register(
    ArchSpec(
        arch_id="dpr-bert-base",
        family="bert",
        model_cfg=BertConfig(
            name="bert-base-uncased",
            n_layers=12,
            d_model=768,
            n_heads=12,
            d_ff=3072,
            vocab_size=30522,
            max_position=512,
            dtype=jnp.bfloat16,
            remat="full",
        ),
        shapes=DPR_SHAPES,
    )
)
