"""Architecture registry: ``get_arch(arch_id)`` -> ArchSpec.

One module per assigned architecture (exact public-literature configs) plus
the paper's own dual-encoder (dpr-bert-base).
"""

from repro.configs.base import ArchSpec, ShapeCell, get_arch, register, list_archs

# import for registration side effects
from repro.configs import (  # noqa: F401
    dpr_bert_base,
    stablelm_3b,
    internlm2_1p8b,
    qwen1p5_110b,
    qwen3_moe_235b,
    olmoe_1b_7b,
    schnet,
    dcn_v2,
    deepfm,
    dlrm_mlperf,
    dlrm_rm2,
)

__all__ = ["ArchSpec", "ShapeCell", "get_arch", "register", "list_archs"]
