"""dcn-v2 [recsys]: n_dense=13 n_sparse=26 embed_dim=16 n_cross_layers=3
mlp=1024-1024-512 interaction=cross [arXiv:2008.13535]."""

from repro.configs.base import ArchSpec, CRITEO_VOCABS, RECSYS_SHAPES, register
from repro.models.recsys import RecsysConfig

register(
    ArchSpec(
        arch_id="dcn-v2",
        family="recsys",
        model_cfg=RecsysConfig(
            name="dcn-v2",
            n_dense=13,
            vocab_sizes=CRITEO_VOCABS,
            embed_dim=16,
            interaction="cross",
            n_cross_layers=3,
            top_mlp=(1024, 1024, 512),
        ),
        shapes=RECSYS_SHAPES,
    )
)
