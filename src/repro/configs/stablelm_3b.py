"""stablelm-3b [dense]: 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304 [hf:stabilityai/stablelm-2-1_6b family; unverified]."""

import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES, register
from repro.models.lm import LMConfig

register(
    ArchSpec(
        arch_id="stablelm-3b",
        family="lm",
        model_cfg=LMConfig(
            name="stablelm-3b",
            n_layers=32,
            d_model=2560,
            n_heads=32,
            n_kv_heads=32,
            d_ff=6912,
            vocab_size=50304,
            head_dim=80,
            rope_theta=10000.0,
            dtype=jnp.bfloat16,
            remat="full",
        ),
        shapes=LM_SHAPES,
        micro_batches={"train_4k": 4},
    )
)
