"""deepfm [recsys]: n_sparse=39 embed_dim=10 mlp=400-400-400 interaction=fm
[arXiv:1703.04247]. 39 fields = criteo's 26 categorical + 13 dense features
bucketized to 1000 bins each (the paper's treatment of numeric fields)."""

from repro.configs.base import ArchSpec, CRITEO_VOCABS, RECSYS_SHAPES, register
from repro.models.recsys import RecsysConfig

register(
    ArchSpec(
        arch_id="deepfm",
        family="recsys",
        model_cfg=RecsysConfig(
            name="deepfm",
            n_dense=0,
            vocab_sizes=CRITEO_VOCABS + (1000,) * 13,
            embed_dim=10,
            interaction="fm",
            top_mlp=(400, 400, 400),
        ),
        shapes=RECSYS_SHAPES,
    )
)
