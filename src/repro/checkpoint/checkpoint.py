"""Fault-tolerant checkpointing.

Layout: <dir>/step_<n>/ containing one .npy per leaf (path-keyed) plus a
manifest.json written LAST — a checkpoint without a complete manifest is
invalid and skipped on restore. Writes go to a tmp dir + atomic rename, so a
preemption mid-save can never corrupt the latest checkpoint. Restore takes a
template pytree (structure + dtypes come from the template; shapes must match
unless a resharder is given).

``CheckpointManager`` adds retention (keep last k), async save (snapshot to
host then write on a background thread), and resume-from-latest-valid.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Callable, List, Optional

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path) or "leaf"
        out.append((key, leaf))
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Atomic checkpoint write. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:012d}")
    tmp = final + f".tmp.{os.getpid()}.{threading.get_ident()}"
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(jax.device_get(tree))
    manifest = {"step": step, "leaves": [], "time": time.time()}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    # manifest written last: its presence marks the checkpoint complete
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _valid_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        if os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = _valid_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str,
    template: Any,
    step: Optional[int] = None,
    *,
    resharder: Optional[Callable[[str, np.ndarray, Any], Any]] = None,
) -> tuple[Any, int]:
    """Restore into the structure of ``template``. Skips invalid/corrupt
    checkpoints, falling back to the previous valid one."""
    steps = _valid_steps(directory)
    if step is not None:
        steps = [s for s in steps if s == step]
    if not steps:
        raise FileNotFoundError(f"no valid checkpoint in {directory}")

    flat_t, treedef = jax.tree_util.tree_flatten(template)
    keys = [k for k, _ in _flatten_with_paths(template)]

    for s in reversed(steps):
        path = os.path.join(directory, f"step_{s:012d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            by_key = {m["key"]: m for m in manifest["leaves"]}
            leaves = []
            for key, tmpl in zip(keys, flat_t):
                meta = by_key[key]
                arr = np.load(os.path.join(path, meta["file"]))
                if resharder is not None:
                    arr = resharder(key, arr, tmpl)
                if tuple(arr.shape) != tuple(np.shape(tmpl)):
                    raise ValueError(
                        f"shape mismatch for {key}: ckpt {arr.shape} vs "
                        f"template {np.shape(tmpl)} (pass a resharder)"
                    )
                leaves.append(arr.astype(np.asarray(tmpl).dtype))
            return jax.tree_util.tree_unflatten(treedef, leaves), s
        except (KeyError, ValueError, OSError, json.JSONDecodeError) as e:
            # corrupt / incompatible — try the previous checkpoint
            last_err = e
            continue
    raise RuntimeError(f"all checkpoints in {directory} failed to restore: {last_err}")


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, *, block: bool = False):
        snapshot = jax.device_get(tree)  # snapshot NOW; write later

        def _write():
            try:
                save_checkpoint(self.directory, step, snapshot)
                self._gc()
            except BaseException as e:
                self._error = e

        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
            if self._error:
                raise self._error

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, template: Any):
        return restore_checkpoint(self.directory, template)

    def _gc(self):
        steps = _valid_steps(self.directory)
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:012d}"), ignore_errors=True
            )
