"""Gradient compression with error feedback for the DP all-reduce.

At pod scale the contrastive methods are gradient-all-reduce-bound between
pods (110M-2B dense params / step). Compressing the all-reduced gradients to
bf16 halves the "pod" axis (DCN) traffic; the residual (fp32 - bf16) is fed
back into the next step so the compression error does not accumulate
(error-feedback SGD, Seide et al. / Karimireddy et al.). Exactness
degradation and error-feedback recovery are tested.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any  # params-shaped fp32


def init_error_feedback(params: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    )


def compress_with_feedback(
    grads: Any, state: ErrorFeedbackState, dtype=jnp.bfloat16
) -> Tuple[Any, ErrorFeedbackState]:
    """Returns (compressed grads ready for the all-reduce, new residual)."""

    def leaf(g, r):
        corrected = g.astype(jnp.float32) + r
        q = corrected.astype(dtype)
        return q, corrected - q.astype(jnp.float32)

    pairs = jax.tree_util.tree_map(leaf, grads, state.residual)
    q = jax.tree_util.tree_map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    r = jax.tree_util.tree_map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return q, ErrorFeedbackState(residual=r)
