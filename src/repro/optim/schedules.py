"""Learning-rate schedules.

The paper (Appendix B) uses linear warmup (1,237 steps) followed by linear
decay to zero — implemented here as ``linear_warmup_linear_decay``.
"""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def schedule(step):
        return jnp.asarray(lr, dtype=jnp.float32)

    return schedule


def linear_warmup_linear_decay(peak_lr: float, warmup_steps: int, total_steps: int):
    """Paper's schedule: 0 -> peak over ``warmup_steps``, then linearly to 0 at
    ``total_steps``."""
    warmup_steps = max(int(warmup_steps), 1)
    total_steps = max(int(total_steps), warmup_steps + 1)

    def schedule(step):
        step = jnp.asarray(step, dtype=jnp.float32)
        warm = step / warmup_steps
        decay = (total_steps - step) / float(total_steps - warmup_steps)
        frac = jnp.where(step < warmup_steps, warm, decay)
        return peak_lr * jnp.clip(frac, 0.0, 1.0)

    return schedule


def cosine_decay(peak_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.0):
    warmup_steps = max(int(warmup_steps), 1)
    total_steps = max(int(total_steps), warmup_steps + 1)

    def schedule(step):
        step = jnp.asarray(step, dtype=jnp.float32)
        warm = step / warmup_steps
        prog = jnp.clip((step - warmup_steps) / (total_steps - warmup_steps), 0.0, 1.0)
        cos = min_ratio + (1.0 - min_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(step < warmup_steps, warm, cos)

    return schedule
