from repro.optim.adamw import adamw, sgd, apply_updates, clip_by_global_norm, chain, GradientTransformation
from repro.optim.schedules import linear_warmup_linear_decay, constant_schedule, cosine_decay

__all__ = [
    "adamw",
    "sgd",
    "apply_updates",
    "clip_by_global_norm",
    "chain",
    "GradientTransformation",
    "linear_warmup_linear_decay",
    "constant_schedule",
    "cosine_decay",
]
