"""AdamW + gradient clipping, implemented natively (optax is not available in
this offline environment). The interface mirrors optax's
``GradientTransformation`` so the rest of the framework is insulated from the
implementation.

Paper hyperparameters (Appendix B): AdamW, lr 2e-5, eps 1e-8, weight decay 0,
global-norm clip 2.0, linear warmup + linear decay.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.common.treemath import tree_global_norm


class GradientTransformation(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)


class AdamWState(NamedTuple):
    count: jnp.ndarray  # () int32
    mu: Any             # first moment (params-shaped, fp32)
    nu: Any             # second moment (params-shaped, fp32)
    master: Any = None  # fp32 master params (only with keep_master_params)


def adamw(
    learning_rate: Union[float, Callable],
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    mask: Optional[Callable[[Any], Any]] = None,
    moment_dtype=jnp.float32,
    keep_master_params: bool = False,
) -> GradientTransformation:
    """AdamW with a PrecisionPolicy-shaped dtype story (core/precision.py).

    ``mask(params)`` may return a pytree of bools selecting which leaves get
    weight decay (e.g. exclude LayerNorm/bias, the BERT convention).
    ``moment_dtype=bf16`` halves optimizer-state HBM for the 100B+ configs
    (momentum quantization; the accumulation arithmetic stays fp32).

    Master params: under the shipped precision presets the *train-state
    params are already the fp32 masters* (``param_dtype=fp32``) and the
    encoders make transient bf16 compute copies at application, so nothing
    extra is stored here. ``keep_master_params=True`` supports the converse
    layout — params stored in a low precision (true bf16 weights) — by
    carrying fp32 masters inside the optimizer state: moments and the update
    arithmetic run on the masters, and the emitted update re-rounds the
    low-precision params to the new master value each step, so repeated
    rounding never accumulates across steps (tracks the fp32 trajectory to
    bf16 tolerance — tests/test_precision.py).
    """

    def init(params):
        mu = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=moment_dtype), params
        )
        nu = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=moment_dtype), params
        )
        master = None
        if keep_master_params:
            master = jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32), params
            )
        return AdamWState(
            count=jnp.zeros((), jnp.int32), mu=mu, nu=nu, master=master
        )

    def update(grads, state, params):
        count = state.count + 1
        lr = learning_rate(count) if callable(learning_rate) else learning_rate

        mu = jax.tree_util.tree_map(
            lambda m, g: (
                b1 * m.astype(jnp.float32) + (1.0 - b1) * g.astype(jnp.float32)
            ).astype(moment_dtype),
            state.mu,
            grads,
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: (
                b2 * v.astype(jnp.float32) + (1.0 - b2) * jnp.square(g.astype(jnp.float32))
            ).astype(moment_dtype),
            state.nu,
            grads,
        )
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        if mask is not None and params is not None:
            wd_mask = mask(params)
        else:
            wd_mask = jax.tree_util.tree_map(lambda _: True, params)

        if keep_master_params:
            def leaf_master(m, v, p, mstr, use_wd):
                m = m.astype(jnp.float32)
                v = v.astype(jnp.float32)
                step = (m / c1) / (jnp.sqrt(v / c2) + eps)
                if weight_decay:
                    step = step + jnp.where(use_wd, weight_decay, 0.0) * mstr
                return mstr - lr * step

            new_master = jax.tree_util.tree_map(
                leaf_master, mu, nu, params, state.master, wd_mask
            )
            # re-round from the fp32 master every step: p_new ends up at
            # round(master_new), so low-precision rounding never compounds
            updates = jax.tree_util.tree_map(
                lambda nm, p: nm.astype(p.dtype) - p, new_master, params
            )
            return updates, AdamWState(
                count=count, mu=mu, nu=nu, master=new_master
            )

        def leaf_update(m, v, p, use_wd):
            m = m.astype(jnp.float32)
            v = v.astype(jnp.float32)
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay:
                step = step + jnp.where(use_wd, weight_decay, 0.0) * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree_util.tree_map(leaf_update, mu, nu, params, wd_mask)
        return updates, AdamWState(count=count, mu=mu, nu=nu, master=None)

    return GradientTransformation(init=init, update=update)


def sgd(learning_rate: Union[float, Callable]) -> GradientTransformation:
    """Plain SGD. Used by identity tests (AdamW's sign-like step-1 update
    amplifies fp-level gradient noise, making post-update param comparison
    ill-conditioned)."""

    def init(params):
        del params
        return jnp.zeros((), jnp.int32)

    def update(grads, state, params=None):
        del params
        count = state + 1
        lr = learning_rate(count) if callable(learning_rate) else learning_rate
        updates = jax.tree_util.tree_map(lambda g: (-lr * g).astype(g.dtype), grads)
        return updates, count

    return GradientTransformation(init=init, update=update)


class ClipState(NamedTuple):
    pass


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        del params
        return ClipState()

    def update(grads, state, params=None):
        del params
        norm = tree_global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
        clipped = jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads)
        return clipped, state

    return GradientTransformation(init=init, update=update)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transformations left-to-right (like optax.chain)."""

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)
