"""Elastic scaling: reshard checkpoints and data streams across mesh resizes.

Two halves:

  * **Weights/optimizer**: checkpoints are stored unsharded-on-host (per-leaf
    npy), so weight resharding is free — restore with the new mesh's sharding
    tree. What needs care is *shape-coupled* state: ContAccum's memory banks
    (capacity may change with the new memory budget) and batch-shaped
    accumulators. ``reshard_bank`` grows/shrinks a FIFO bank preserving the
    newest entries in order.

  * **Data stream**: the loader's index stream is keyed by (seed, epoch) and
    partitioned by host_id::n_hosts strides (data/loader.py), so resuming
    with a different host count replays the SAME global sample sequence —
    no skipped or duplicated examples across a resize (tested in
    tests/test_runtime.py::test_elastic_loader_resize).

``plan_resize`` computes the new DP/TP layout for a device-count change and
validates divisibility of every global batch in flight.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.memory_bank import BankState


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    n_devices: int
    dp: int
    tp: int

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.dp, self.tp)


def plan_resize(
    n_devices: int,
    *,
    global_batch: int,
    tp: Optional[int] = None,
    max_tp: int = 16,
) -> MeshPlan:
    """Pick (dp, tp) for a new device count.

    TP is kept at the old value when it still divides; otherwise the largest
    power-of-two tp <= max_tp that divides n_devices. DP must divide the
    global batch (the batch is NOT rescaled on resize — learning dynamics are
    preserved; per-device batch changes instead)."""
    # candidate tp values: every divisor of n_devices up to max_tp, the
    # requested tp first, then descending (keep model-parallel capacity)
    divisors = [t for t in range(1, max_tp + 1) if n_devices % t == 0]
    candidates = sorted(
        divisors, key=lambda t: (t != tp, -t)
    )
    for t in candidates:
        dp = n_devices // t
        if global_batch % dp == 0:
            return MeshPlan(n_devices=n_devices, dp=dp, tp=t)
    raise ValueError(
        f"no (dp, tp<= {max_tp}) layout of {n_devices} devices divides "
        f"global batch {global_batch}; choose a batch-compatible mesh"
    )


def reshard_bank(bank_arrays: Dict[str, np.ndarray], new_capacity: int) -> Dict[str, np.ndarray]:
    """Resize a FIFO bank (host-side np arrays from a checkpoint), keeping the
    newest entries. Returned arrays encode a ring with head at the next write
    position, oldest-first layout (head = n_kept % capacity when not full).
    """
    buf, valid, head, age = (
        bank_arrays["buf"],
        bank_arrays["valid"],
        int(bank_arrays["head"]),
        bank_arrays["age"],
    )
    cap, d = buf.shape
    # order oldest -> newest, keep only valid
    perm = (head + np.arange(cap)) % cap
    buf_o, valid_o, age_o = buf[perm], valid[perm], age[perm]
    keep = np.flatnonzero(valid_o)
    buf_o, age_o = buf_o[keep], age_o[keep]
    n_keep = min(len(buf_o), new_capacity)
    buf_o, age_o = buf_o[len(buf_o) - n_keep:], age_o[len(age_o) - n_keep:]

    new_buf = np.zeros((new_capacity, d), buf.dtype)
    new_valid = np.zeros((new_capacity,), bool)
    new_age = np.zeros((new_capacity,), age.dtype)
    new_buf[:n_keep] = buf_o
    new_valid[:n_keep] = True
    new_age[:n_keep] = age_o
    new_head = n_keep % new_capacity if n_keep < new_capacity else 0
    return {
        "buf": new_buf,
        "valid": new_valid,
        "head": np.asarray(new_head, np.int32),
        "age": new_age,
    }


def bank_to_arrays(bank: BankState) -> Dict[str, np.ndarray]:
    return {
        "buf": np.asarray(bank.buf),
        "valid": np.asarray(bank.valid),
        "head": np.asarray(bank.head),
        "age": np.asarray(bank.age),
    }


def arrays_to_bank(arrs: Dict[str, np.ndarray]) -> BankState:
    import jax.numpy as jnp

    return BankState(
        buf=jnp.asarray(arrs["buf"]),
        valid=jnp.asarray(arrs["valid"]),
        head=jnp.asarray(arrs["head"], jnp.int32),
        age=jnp.asarray(arrs["age"], jnp.int32),
    )


def reshard_state_banks(state, new_capacity_q: int, new_capacity_p: int):
    """ContrastiveState -> ContrastiveState with resized dual banks (the
    elastic-resize path for the paper's method; dual symmetry is preserved by
    resizing both banks together)."""
    from repro.core.types import ContrastiveState

    bq = arrays_to_bank(reshard_bank(bank_to_arrays(state.bank_q), new_capacity_q))
    bp = arrays_to_bank(reshard_bank(bank_to_arrays(state.bank_p), new_capacity_p))
    return ContrastiveState(
        step=state.step,
        params=state.params,
        opt_state=state.opt_state,
        bank_q=bq,
        bank_p=bp,
    )
