from repro.distribution.sharding import (
    ShardingRules,
    LM_RULES,
    BERT_RULES,
    GNN_RULES,
    RECSYS_RULES,
    make_param_shardings,
    spec_for_path,
    dp_axes,
)

__all__ = [
    "ShardingRules",
    "LM_RULES",
    "BERT_RULES",
    "GNN_RULES",
    "RECSYS_RULES",
    "make_param_shardings",
    "spec_for_path",
    "dp_axes",
]
