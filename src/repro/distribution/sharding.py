"""Logical sharding rules: parameter-path regex -> PartitionSpec.

Models stay sharding-agnostic; these tables encode the parallelism plan:

  * DP    : batch over ("pod", "data") (pure DP across pods).
  * FSDP  : weights additionally sharded over "data" on the non-TP dim
            (ZeRO-3; XLA all-gathers at use). Required for the >=100B configs.
  * TP    : Megatron tensor parallel over "model" — attention q-heads, FFN
            hidden, vocab/lm_head, expert dim (=EP for MoE), embedding-table
            rows (recsys).
  * GQA   : kv projections with kv_heads < |model| are sharded over "model"
            on the *weight* only (FSDP-style); activations keep kv heads
            replicated, so attention runs without resharding.
  * SP    : (hillclimb lever) sequence dim of the residual stream over
            "model" between blocks.

Divisibility across all five LM configs x mesh (16,16)/(2,16,16) is asserted
in tests/test_sharding.py.
"""

from __future__ import annotations

import re
from typing import Any, List, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

ShardingRules = List[Tuple[str, P]]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes for a mesh: ("pod","data") or ("data",)."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def dp_ring_size(mesh: Mesh) -> int:
    """D — the number of devices on the flattened DP ring (the product of
    the DP axis sizes). This is the divisor in every 1/D memory statement:
    sharded banks hold ``bank_size/D`` rows per device, and the ring-streamed
    loss (``loss_comm='ring'``) peaks at ``O(N_mem*d/D)`` transient bytes per
    eval. ``DistCtx.ring_perm`` builds its ppermute table over the same
    flattened ring, in ``DistCtx.shard_index`` (major-to-minor) order."""
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------- LM family
# paths look like: layers/attn/wq, layers/ffn/w_gate, embed, lm_head, ...
LM_RULES: ShardingRules = [
    # patterns use (^|/) anchors so they also match inside optimizer-state
    # subtrees (e.g. "1/mu/layers/attn/wq") — moments shard like their params.
    (r"(^|/)embed$", P("model", "data")),                 # (V, d): vocab TP, d FSDP
    (r"(^|/)lm_head$", P("data", "model")),               # (d, V)
    (r"(^|/)final_norm$", P()),
    (r"(^|/)layers/ln\d$", P(None, None)),
    (r"(^|/)layers/attn/wq$", P(None, "data", "model")),  # (L, d, H*dh)
    (r"(^|/)layers/attn/wk$", P(None, "data", "model")),  # weight-only TP (GQA)
    (r"(^|/)layers/attn/wv$", P(None, "data", "model")),
    (r"(^|/)layers/attn/wo$", P(None, "model", "data")),  # (L, H*dh, d)
    (r"(^|/)layers/attn/b[qkv]$", P(None, "model")),
    (r"(^|/)layers/ffn/router$", P(None, None, None)),    # (L, d, E) small
    # MoE expert weights: EP over "model", FSDP over "data" on the
    # contraction dim. §Perf C2 tried FSDP on the OUTPUT dim (hoping for
    # ZeRO-3 weight all-gathers) — measured 1.9x WORSE: GSPMD all-gathered
    # the xe activations instead because the output-dim "data" placement
    # conflicts with the data-sharded group dim. Refuted; kept as-is.
    (r"(^|/)layers/ffn/w_gate$", P(None, "model", "data", None)),  # (L,E,d,f)
    (r"(^|/)layers/ffn/w_up$", P(None, "model", "data", None)),
    (r"(^|/)layers/ffn/w_down$", P(None, "model", None, "data")),  # (L,E,f,d)
]
# dense-FFN overrides (3D leaves share names with MoE 4D ones; resolved by rank)
LM_DENSE_FFN = [
    (r"(^|/)layers/ffn/w_gate$", P(None, "data", "model")),   # (L, d, ff)
    (r"(^|/)layers/ffn/w_up$", P(None, "data", "model")),
    (r"(^|/)layers/ffn/w_down$", P(None, "model", "data")),   # (L, ff, d)
]

# ------------------------------------------------------------- BERT dual tower
BERT_RULES: ShardingRules = [
    (r"embed/word$", P("model", None)),
    (r"embed/(pos|type)$", P(None, None)),
    (r"embed/ln_[sb]$", P()),
    (r"layers/wqkv$", P(None, "data", "model")),
    (r"layers/wo$", P(None, "model", "data")),
    (r"layers/w1$", P(None, "data", "model")),
    (r"layers/w2$", P(None, "model", "data")),
    (r"layers/(b1)$", P(None, "model")),
    (r"layers/(bqkv|bo|b2|ln\d_[sb])$", P(None, None)),
]

# ------------------------------------------------------------------ GNN
GNN_RULES: ShardingRules = [
    (r".*", P()),  # SchNet is tiny (~100k params): replicate everything
]


# ------------------------------------------------- contrastive memory banks
def bank_rules(dp: Tuple[str, ...], shard_banks: bool) -> ShardingRules:
    """Partition rules for the ContrastiveState memory banks: with
    ``shard_banks`` the ring rows (buf/valid/age) are sharded over the DP
    axes — each device owns a contiguous ``capacity/D`` slot block, matching
    memory_bank.shard_push's shard-major global layout — while the global
    head stays replicated. Without it the banks replicate (the default).

    The same sharded layout serves both ``loss_comm`` modes: 'all_gather'
    concatenates the shards (major-to-minor DP order == global slot order)
    per loss eval, 'ring' leaves them in place and streams them around the
    DP ring — shard s's rows are global slots [s*cap/D, (s+1)*cap/D) either
    way, so the two modes index identical global columns."""
    if not shard_banks:
        return [(r"bank_[qp]\b", P())]
    return [
        (r"bank_[qp].*head$", P()),
        (r"bank_[qp]\b", P(dp)),
    ]


def contrastive_state_spec(dp: Tuple[str, ...], shard_banks: bool):
    """ContrastiveState-shaped PartitionSpec prefix-tree for shard_map
    in/out_specs on the StepProgram update: params/optimizer replicated
    (pure DP), banks per ``bank_rules``. Pair with a batch spec of
    ``P(dp)`` on every RetrievalBatch leaf.

    Specs are dtype-free, so the same tree serves every PrecisionPolicy
    (core/precision.py): the bank leaves' dtype flows from the state built
    by ``init_state`` (bf16 rings under 'bf16_banks' shard exactly like fp32
    ones — the two memory levers compose to bank bytes / (2·D))."""
    from repro.core.memory_bank import bank_spec
    from repro.core.types import ContrastiveState

    banks = bank_spec(dp) if shard_banks else bank_spec(None)
    return ContrastiveState(
        step=P(), params=P(), opt_state=P(), bank_q=banks, bank_p=banks
    )

# ---------------------------------------------------------------- recsys
# The stacked table is row-sharded over BOTH in-pod axes: dlrm-mlperf is
# 188M rows x 128 = 96 GB fp32; over "model" alone (16) that is 6 GB of
# params + 12 GB of Adam moments per chip — over the 16 GB v5e budget.
# 256-way row sharding brings the table memory to ~1.1 GB/chip total.
RECSYS_RULES: ShardingRules = [
    (r"(^|/)table$", P(("model", "data"), None)),  # row-sharded embedding table
    (r"(^|/)w_first$", P(("model", "data"))),      # DeepFM first-order weights
    (r".*", P()),                                  # MLPs replicated (small)
]


def _path_key(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def spec_for_path(key: str, leaf, rules: ShardingRules, dense_ffn: bool = False) -> P:
    if dense_ffn and np.ndim(leaf) == 3:
        for pattern, spec in LM_DENSE_FFN:
            if re.search(pattern, key):
                return spec
    for pattern, spec in rules:
        if re.search(pattern, key):
            # drop trailing spec axes beyond the leaf's rank
            if len(spec) > np.ndim(leaf):
                spec = P(*tuple(spec)[: np.ndim(leaf)])
            return spec
    return P()


def _mesh_axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def validate_spec(mesh: Mesh, spec: P, shape: Sequence[int], key: str = "") -> P:
    """Drop axes that do not divide (with a loud comment trail in tests);
    production rule tables are divisibility-checked in tests, this is the
    runtime safety net for ad-hoc configs."""
    fixed = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is None:
            fixed.append(None)
            continue
        n = _mesh_axis_size(mesh, axis)
        fixed.append(axis if dim % n == 0 else None)
    while fixed and fixed[-1] is None:
        fixed.pop()
    return P(*fixed)


def make_param_shardings(
    mesh: Mesh, params: Any, rules: ShardingRules, *, dense_ffn: bool = False
) -> Any:
    """Pytree of NamedShardings matching ``params``; multi-pod meshes reuse the
    same rules (pod is a pure-DP axis and never appears in weight specs)."""

    def per_leaf(path, leaf):
        key = _path_key(path)
        spec = spec_for_path(key, leaf, rules, dense_ffn=dense_ffn)
        spec = validate_spec(mesh, spec, np.shape(leaf), key)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(per_leaf, params)


def make_spec_tree(mesh: Mesh, params: Any, rules: ShardingRules, *, dense_ffn: bool = False):
    """Like make_param_shardings but returns raw PartitionSpecs (for jit
    in_shardings where the tree contains ShapeDtypeStructs)."""

    def per_leaf(path, leaf):
        key = _path_key(path)
        spec = spec_for_path(key, leaf, rules, dense_ffn=dense_ffn)
        return validate_spec(mesh, spec, np.shape(leaf), key)

    return jax.tree_util.tree_map_with_path(per_leaf, params)
