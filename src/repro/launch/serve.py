"""Serving driver: build a passage index with the passage tower, start the
dynamic-batching retrieval server, and run a load test with mixed
single-query requests. CPU-runnable end to end at reduced scale.

  PYTHONPATH=src python -m repro.launch.serve --n-passages 1024 --n-queries 64
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.data.retrieval import SyntheticRetrievalCorpus
from repro.launch.train import tiny_bert
from repro.models.bert import bert_encode, init_bert
from repro.runtime.server import build_index, make_retrieval_server


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-passages", type=int, default=1024)
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--top-k", type=int, default=20)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = tiny_bert()
    params = init_bert(jax.random.PRNGKey(args.seed), cfg)
    corpus = SyntheticRetrievalCorpus(
        n_passages=args.n_passages, q_len=16, p_len=32, seed=args.seed
    )

    t0 = time.time()
    index = build_index(
        lambda toks: bert_encode(params, cfg, toks), corpus.passages, batch=128
    )
    print(f"index: {index.shape} built in {time.time()-t0:.2f}s")

    server = make_retrieval_server(
        lambda toks: bert_encode(params, cfg, toks),
        index,
        k=args.top_k,
        max_batch=args.max_batch,
    ).start()
    try:
        t0 = time.time()
        futures = [
            server.submit(corpus.queries[i]) for i in range(args.n_queries)
        ]
        hits = 0
        for i, fut in enumerate(futures):
            ids, scores = fut.get(timeout=60)
            hits += int(i in ids)       # untrained model: recall is luck; the
        dt = time.time() - t0            # load test validates the serving path
        sizes = server.batch_sizes
        print(
            f"served {args.n_queries} queries in {dt:.2f}s "
            f"({args.n_queries/dt:.1f} qps), top-{args.top_k} recall "
            f"{hits/args.n_queries:.3f}, mean coalesced batch "
            f"{np.mean(sizes):.1f} (max {max(sizes)})"
        )
    finally:
        server.stop()


if __name__ == "__main__":
    main()
