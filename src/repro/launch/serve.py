"""Serving driver on the Retriever API: load a trainer checkpoint (or init
fresh), build the passage index, start the dynamic-batching server, and run
a load test with single-query requests. CPU-runnable end to end.

  PYTHONPATH=src python -m repro.launch.serve --n-passages 1024 --n-queries 64

Serve a model trained by launch/train.py (same tiny-bert tower config):

  PYTHONPATH=src python -m repro.launch.train --steps 100 --checkpoint-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.serve --ckpt /tmp/ckpt

Sharded bf16 index over an 8-way DP mesh with the fused Pallas search
kernel (on CPU force the host devices first):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.serve \\
      --dp 8 --precision bf16_banks --search-impl fused
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.precision import PRECISION_PRESETS
from repro.data.retrieval import SyntheticRetrievalCorpus
from repro.launch.train import tiny_bert
from repro.models.towers import make_bert_dual_encoder
from repro.retrieval import (
    Retriever,
    RetrieverConfig,
    load_trained_params,
    make_dp_mesh,
    make_server,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None,
                    help="runtime/trainer.py checkpoint dir: serve the "
                         "trained params instead of a fresh init")
    ap.add_argument("--dp", type=int, default=0,
                    help="shard the index over an N-way DP mesh (0 = "
                         "replicated; needs jax.device_count() >= N)")
    ap.add_argument("--precision", default="fp32",
                    choices=sorted(PRECISION_PRESETS),
                    help="PrecisionPolicy preset: queries encoded/scored in "
                         "compute dtype, index stored in bank dtype "
                         "(bf16_banks halves index bytes), scores fp32")
    ap.add_argument("--search-impl", default="dense",
                    choices=["dense", "fused"],
                    help="per-device scoring: blocked-scan top-k vs the "
                         "fused Pallas QK^T + running-top-k kernel")
    ap.add_argument("--n-passages", type=int, default=1024)
    ap.add_argument("--n-queries", type=int, default=64)
    ap.add_argument("--top-k", type=int, default=20)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = tiny_bert()
    enc = make_bert_dual_encoder(cfg, precision=args.precision)
    if args.ckpt:
        params, step = load_trained_params(args.ckpt)
        print(f"restored trained params from {args.ckpt} (step {step})")
    else:
        params = enc.init(jax.random.PRNGKey(args.seed))
    corpus = SyntheticRetrievalCorpus(
        n_passages=args.n_passages, q_len=16, p_len=32, seed=args.seed
    )

    rcfg = RetrieverConfig(
        top_k=args.top_k,
        search_impl=args.search_impl,
        index_layout="sharded" if args.dp else "replicated",
        precision=args.precision,
        encode_batch=128,
    )
    mesh = make_dp_mesh(args.dp) if args.dp else None
    retriever = Retriever(enc, params, rcfg, mesh=mesh)

    t0 = time.time()
    store = retriever.build_index(corpus.passages)
    print(
        f"index: {store.reps.shape} ({str(store.reps.dtype)}, "
        f"{store.bytes_per_device()/1024:.0f} KiB/device over "
        f"{store.shards} shard(s)) built in {time.time()-t0:.2f}s"
    )

    server = make_server(
        retriever, max_batch=args.max_batch
    ).start()
    try:
        t0 = time.time()
        futures = [
            server.submit(corpus.queries[i]) for i in range(args.n_queries)
        ]
        hits = 0
        for i, fut in enumerate(futures):
            ids, scores = fut.get(timeout=60)
            hits += int(i in ids)
        dt = time.time() - t0
        sizes = server.batch_sizes
        stats = {
            "qps": args.n_queries / dt,
            "recall": hits / args.n_queries,
            "batch_mean": float(np.mean(sizes)),
            "batch_max": int(max(sizes)),
            "index_bytes_per_device": store.bytes_per_device(),
        }
        print(
            f"served {args.n_queries} queries in {dt:.2f}s "
            f"({stats['qps']:.1f} qps), top-{args.top_k} recall "
            f"{stats['recall']:.3f}, mean coalesced batch "
            f"{stats['batch_mean']:.1f} (max {stats['batch_max']})"
        )
        return stats
    finally:
        server.stop()


if __name__ == "__main__":
    main()
