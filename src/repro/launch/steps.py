"""Cell programs: (architecture x shape cell x mesh) -> jit-able step function
plus ShapeDtypeStruct inputs carrying NamedShardings (the shannon/kernels
dry-run pattern: weak-type-correct, shardable, zero device allocation).

Every assigned shape cell lowers one of:
  train          LM causal-LM training step (microbatched grad accumulation)
  prefill        LM KV-cache build + last-position logits
  decode         LM one-token serve step against a seq_len KV cache
  gnn_full/...   SchNet training step (full graph / sampled block / molecules)
  recsys_train   DLRM/DCN/DeepFM BCE training step
  recsys_serve   forward scoring
  recsys_retrieval  1 query x 1M candidates factorized scoring
  contrastive    the paper's ContAccum update at pod scale (dual banks,
                 cross-device in-batch negatives via GSPMD)

Irregular sizes (edge counts, candidate counts) are padded up to the device
count with explicit validity masks — static shapes everywhere, masked
elements contribute zero (recorded in ``static_info['padded']``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.treemath import tree_add, tree_scale, tree_zeros_like
from repro.configs import get_arch, list_archs
from repro.configs.base import ArchSpec, ShapeCell
from repro.core.dist import get_shard_map
from repro.core.methods import build_step_program, init_state
from repro.core.precision import bank_bytes_per_device, resolve_precision
from repro.core.types import ContrastiveConfig, RetrievalBatch
from repro.distribution.sharding import (
    BERT_RULES,
    GNN_RULES,
    LM_RULES,
    RECSYS_RULES,
    bank_rules,
    contrastive_state_spec,
    dp_axes,
    make_param_shardings,
)
from repro.models.bert import BertConfig
from repro.models.gnn import GraphBatch, SchNetConfig, init_schnet, schnet_loss
from repro.models.lm import (
    KVCache,
    LMConfig,
    decode_step,
    init_lm,
    lm_loss,
    prefill,
)
from repro.models.recsys import (
    RecsysConfig,
    bce_loss,
    forward as recsys_forward,
    init_recsys,
    score_candidates,
)
from repro.models.towers import make_bert_dual_encoder
from repro.optim.adamw import adamw, apply_updates, chain, clip_by_global_norm
from repro.optim.schedules import linear_warmup_linear_decay


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt: Any


@dataclasses.dataclass
class CellProgram:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable
    args: Tuple[Any, ...]               # ShapeDtypeStructs with shardings
    donate_argnums: Tuple[int, ...]
    static_info: dict


# bf16 Adam moments for the >=100B configs (HBM budget; see configs notes)
MOMENT_DTYPE = {
    "qwen1.5-110b": jnp.bfloat16,
    "qwen3-moe-235b-a22b": jnp.bfloat16,
}


# --------------------------------------------------------------------- utils
def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def _sds(mesh: Mesh, shape, dtype, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _shard_like(mesh: Mesh, tree, rules, *, dense_ffn: bool = False):
    """eval_shape tree -> same tree of SDS with rule-derived shardings."""
    sh = make_param_shardings(mesh, tree, rules, dense_ffn=dense_ffn)
    return jax.tree_util.tree_map(
        lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h), tree, sh
    )


def _pad_to(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def _constrain(mesh: Mesh, x, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _make_tx(arch_id: str, *, lr: float = 3e-4, clip: float = 1.0):
    sched = linear_warmup_linear_decay(lr, 2000, 200_000)
    return chain(
        clip_by_global_norm(clip),
        adamw(sched, moment_dtype=MOMENT_DTYPE.get(arch_id, jnp.float32)),
    )


# ---------------------------------------------------------------- LM: train
def _lm_flops(cfg: LMConfig, tokens: int, *, train: bool) -> float:
    n = cfg.active_param_count()
    mult = 6.0 if train else 2.0
    # attention score/value flops (not in 6ND): 2 * 2 * S * tokens * H * dh,
    # halved for causal masking
    attn = 2.0 * tokens * cfg.n_heads * cfg.dh * cfg.n_layers
    return mult * n * tokens + (3.0 if train else 1.0) * attn


def _lm_train_program(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    cfg: LMConfig = arch.model_cfg
    B, S = cell.params["global_batch"], cell.params["seq_len"]
    dp = dp_axes(mesh)
    dps = _axes_size(mesh, dp)
    # microbatch count: honor the config but keep every microbatch shardable
    m = max(1, min(arch.micro_batch(cell.name), B // dps))
    while B % m or (B // m) % dps:
        m -= 1

    tx = _make_tx(arch.arch_id)

    def loss_fn(params, tokens, targets):
        return lm_loss(params, cfg, tokens, targets)

    def train_step(state: TrainState, tokens, targets):
        # tokens/targets: (m, B//m, S), microbatch-major
        def micro(g_acc, inp):
            tk, tg = inp
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, tk, tg
            )
            return tree_add(g_acc, g), loss

        grads, losses = jax.lax.scan(
            micro, tree_zeros_like(state.params), (tokens, targets)
        )
        grads = tree_scale(grads, 1.0 / m)
        updates, opt = tx.update(grads, state.opt, state.params)
        params = apply_updates(state.params, updates)
        return TrainState(state.step + 1, params, opt), {"loss": losses.mean()}

    dense_ffn = cfg.moe is None
    params_s = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    opt_s = jax.eval_shape(_make_tx(arch.arch_id).init, params_s)
    state = TrainState(
        step=_sds(mesh, (), jnp.int32, P()),
        params=_shard_like(mesh, params_s, LM_RULES, dense_ffn=dense_ffn),
        opt=_shard_like(mesh, opt_s, LM_RULES, dense_ffn=dense_ffn),
    )
    tokens = _sds(mesh, (m, B // m, S), jnp.int32, P(None, dp, None))
    targets = _sds(mesh, (m, B // m, S), jnp.int32, P(None, dp, None))
    return CellProgram(
        arch_id=arch.arch_id,
        shape_name=cell.name,
        kind="train",
        fn=train_step,
        args=(state, tokens, targets),
        donate_argnums=(0,),
        static_info={
            "model_flops": _lm_flops(cfg, B * S, train=True),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "microbatches": m,
            "tokens_per_step": B * S,
        },
    )


# -------------------------------------------------------------- LM: prefill
def _lm_prefill_program(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    cfg: LMConfig = arch.model_cfg
    B, S = cell.params["global_batch"], cell.params["seq_len"]
    dp = dp_axes(mesh)
    cache_spec = P(None, dp, "model", None, None)

    def prefill_step(params, tokens):
        cache, logits = prefill(params, cfg, tokens)
        cache = KVCache(
            k=_constrain(mesh, cache.k, cache_spec),
            v=_constrain(mesh, cache.v, cache_spec),
            length=cache.length,
        )
        return cache, logits

    params_s = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    params = _shard_like(mesh, params_s, LM_RULES, dense_ffn=cfg.moe is None)
    tokens = _sds(mesh, (B, S), jnp.int32, P(dp, None))
    return CellProgram(
        arch_id=arch.arch_id,
        shape_name=cell.name,
        kind="prefill",
        fn=prefill_step,
        args=(params, tokens),
        donate_argnums=(),
        static_info={
            "model_flops": _lm_flops(cfg, B * S, train=False),
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "tokens_per_step": B * S,
        },
    )


# --------------------------------------------------------------- LM: decode
def _lm_decode_program(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    cfg: LMConfig = arch.model_cfg
    B, S = cell.params["global_batch"], cell.params["seq_len"]
    dp = dp_axes(mesh)
    if B == 1:
        # long-context: nothing to shard on batch, context-parallel over
        # every axis (sequence-sharded KV cache -> distributed flash-decode)
        batch_spec = P(None)
        seq_axes: Tuple[str, ...] = _all_axes(mesh)
    else:
        batch_spec = P(dp)
        seq_axes = ("model",)
    cache_spec = P(None, None if B == 1 else dp, seq_axes, None, None)

    def serve_step(params, cache: KVCache, token):
        new_cache, logits = decode_step(params, cfg, cache, token)
        new_cache = KVCache(
            k=_constrain(mesh, new_cache.k, cache_spec),
            v=_constrain(mesh, new_cache.v, cache_spec),
            length=new_cache.length,
        )
        return new_cache, logits

    params_s = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    params = _shard_like(mesh, params_s, LM_RULES, dense_ffn=cfg.moe is None)
    kv_shape = (cfg.n_layers, B, S, cfg.n_kv_heads, cfg.dh)
    cache = KVCache(
        k=_sds(mesh, kv_shape, cfg.dtype, cache_spec),
        v=_sds(mesh, kv_shape, cfg.dtype, cache_spec),
        length=_sds(mesh, (B,), jnp.int32, P()),
    )
    token = _sds(mesh, (B,), jnp.int32, batch_spec)
    kv_bytes = 2 * np.prod(kv_shape) * jnp.dtype(cfg.dtype).itemsize
    return CellProgram(
        arch_id=arch.arch_id,
        shape_name=cell.name,
        kind="decode",
        fn=serve_step,
        args=(params, cache, token),
        donate_argnums=(1,),
        static_info={
            # decode is memory-bound: one full pass over active params + the
            # KV cache per generated token
            "model_flops": 2.0 * cfg.active_param_count() * B
            + 4.0 * B * S * cfg.n_kv_heads * cfg.dh * cfg.n_layers,
            "params": cfg.param_count(),
            "kv_cache_bytes": float(kv_bytes),
            "tokens_per_step": B,
        },
    )


# --------------------------------------------------------------------- GNN
def _gnn_program(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    base: SchNetConfig = arch.model_cfg
    p = cell.params
    all_ax = _all_axes(mesh)
    n_dev = _axes_size(mesh, all_ax)
    kind = cell.kind
    dp = dp_axes(mesh)

    if kind == "gnn_mol":
        cfg = base  # atomic-number embedding, energy regression
        n_graphs = p["batch"]
        n_nodes = p["batch"] * p["n_nodes"]
        n_edges_raw = p["batch"] * p["n_edges"]
        nodes_sds = _sds(mesh, (n_nodes,), jnp.int32, P())
        targets = _sds(mesh, (n_graphs,), jnp.float32, P())
        graph_id = _sds(mesh, (n_nodes,), jnp.int32, P())
        target_mask = None
    else:
        if kind == "gnn_minibatch":
            from repro.data.graph import block_sizes

            n_nodes, n_edges_raw = block_sizes(p["batch_nodes"], p["fanouts"])
        else:
            n_nodes, n_edges_raw = p["n_nodes"], p["n_edges"]
        cfg = dataclasses.replace(
            base, d_feat=p["d_feat"], n_classes=p["n_classes"]
        )
        n_graphs = 1
        nodes_sds = _sds(mesh, (n_nodes, p["d_feat"]), jnp.float32, P())
        targets = _sds(mesh, (n_nodes,), jnp.int32, P())
        graph_id = None
        target_mask = _sds(mesh, (n_nodes,), bool, P())

    n_edges = _pad_to(n_edges_raw, n_dev)
    edge_spec = P(all_ax)
    tx = _make_tx(arch.arch_id, lr=1e-3)

    def train_step(state, nodes, src, dst, edge_dist, node_mask, edge_mask,
                   targets_, target_mask_, graph_id_):
        g = GraphBatch(
            nodes=nodes, src=src, dst=dst, edge_dist=edge_dist,
            node_mask=node_mask, edge_mask=edge_mask, graph_id=graph_id_,
            n_graphs=n_graphs, targets=targets_, target_mask=target_mask_,
        )

        def loss_fn(params):
            return schnet_loss(params, cfg, g)

        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)
        updates, opt = tx.update(grads, state.opt, state.params)
        params = apply_updates(state.params, updates)
        return TrainState(state.step + 1, params, opt), {"loss": loss}

    params_s = jax.eval_shape(lambda: init_schnet(jax.random.PRNGKey(0), cfg))
    opt_s = jax.eval_shape(_make_tx(arch.arch_id, lr=1e-3).init, params_s)
    state = TrainState(
        step=_sds(mesh, (), jnp.int32, P()),
        params=_shard_like(mesh, params_s, GNN_RULES),
        opt=_shard_like(mesh, opt_s, GNN_RULES),
    )
    args = (
        state,
        nodes_sds,
        _sds(mesh, (n_edges,), jnp.int32, edge_spec),
        _sds(mesh, (n_edges,), jnp.int32, edge_spec),
        _sds(mesh, (n_edges,), jnp.float32, edge_spec),
        _sds(mesh, (n_nodes,), bool, P()),
        _sds(mesh, (n_edges,), bool, edge_spec),
        targets,
        target_mask,
        graph_id,
    )
    h = cfg.d_hidden
    # fwd: edge gather/filter (E*(rbf*h + 2h^2)) + node MLPs (N*4h^2), x3 bwd
    model_flops = 3.0 * 2.0 * cfg.n_interactions * (
        n_edges_raw * (cfg.n_rbf * h + 2 * h * h) + n_nodes * 2 * h * h
    )
    return CellProgram(
        arch_id=arch.arch_id,
        shape_name=cell.name,
        kind=kind,
        fn=train_step,
        args=args,
        donate_argnums=(0,),
        static_info={
            "model_flops": model_flops,
            "n_nodes": n_nodes,
            "n_edges": n_edges,
            "padded": {"n_edges": [n_edges_raw, n_edges]},
        },
    )


# ------------------------------------------------------------------- recsys
def _recsys_mlp_flops(cfg: RecsysConfig) -> float:
    total = 0.0
    prev = cfg.n_dense
    for d in cfg.bot_mlp:
        total += 2 * prev * d
        prev = d
    prev = cfg._concat_dim()
    for d in cfg.top_mlp:
        total += 2 * prev * d
        prev = d
    if cfg.interaction == "cross":
        x0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
        total += cfg.n_cross_layers * 2 * x0 * x0
    if cfg.interaction == "dot":
        f = cfg.n_sparse + 1
        total += 2 * f * f * cfg.embed_dim
    return total


def _recsys_program(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    cfg: RecsysConfig = arch.model_cfg
    p = cell.params
    # §Perf iteration A1 (EXPERIMENTS.md): recsys MLPs are replicated over
    # "model", so a ("pod","data")-only batch made every model-rank duplicate
    # the same MLP compute AND all-reduced the full lookup tensor across the
    # whole mesh. Sharding the batch over ALL axes removes the duplication
    # (measured: 16.3x less compute, 9.6x less wire on dlrm-mlperf).
    dp = _all_axes(mesh)
    kind = cell.kind
    # §Perf iteration A3: explicit-collective lookup (all-gather indices ->
    # local-shard masked gather -> psum_scatter). Replaces GSPMD's full-width
    # partial + all-reduce + slice lowering of jnp.take (A2's sharding
    # constraint was ignored — see EXPERIMENTS.md §Perf A). Applied when the
    # batch divides the mesh (retrieval_cand's B=1 user-side lookup stays on
    # the plain path; its cost is negligible next to candidate scoring).
    from repro.models.recsys import make_psum_scatter_lookup

    if kind != "recsys_retrieval" and p["batch"] % _axes_size(mesh, dp) == 0:
        cfg = dataclasses.replace(
            cfg,
            lookup_fn=make_psum_scatter_lookup(
                mesh, table_axes=("model", "data"), batch_axes=dp
            ),
        )
    params_s = jax.eval_shape(lambda: init_recsys(jax.random.PRNGKey(0), cfg))
    params = _shard_like(mesh, params_s, RECSYS_RULES)

    if kind == "recsys_retrieval":
        all_ax = _all_axes(mesh)
        n_dev = _axes_size(mesh, all_ax)
        c = _pad_to(p["n_candidates"], n_dev)

        def retrieval_step(params_, dense, sparse, cand_ids):
            return score_candidates(params_, cfg, dense, sparse, cand_ids)

        args = (
            params,
            _sds(mesh, (1, cfg.n_dense), jnp.float32, P()),
            _sds(mesh, (1, cfg.n_sparse), jnp.int32, P()),
            _sds(mesh, (c,), jnp.int32, P(all_ax)),
        )
        flops = (_recsys_mlp_flops(cfg) + 2 * cfg.n_sparse * cfg.embed_dim) * c
        return CellProgram(
            arch_id=arch.arch_id, shape_name=cell.name, kind=kind,
            fn=retrieval_step, args=args, donate_argnums=(),
            static_info={
                "model_flops": flops,
                "params": cfg.param_count(),
                "padded": {"n_candidates": [p["n_candidates"], c]},
            },
        )

    b = p["batch"]
    dense = _sds(mesh, (b, cfg.n_dense), jnp.float32, P(dp, None))
    sparse = _sds(mesh, (b, cfg.n_sparse), jnp.int32, P(dp, None))

    if kind == "recsys_serve":
        def serve_step(params_, dense_, sparse_):
            return recsys_forward(params_, cfg, dense_, sparse_)

        return CellProgram(
            arch_id=arch.arch_id, shape_name=cell.name, kind=kind,
            fn=serve_step, args=(params, dense, sparse), donate_argnums=(),
            static_info={
                "model_flops": _recsys_mlp_flops(cfg) * b,
                "params": cfg.param_count(),
            },
        )

    # recsys_train
    tx = _make_tx(arch.arch_id, lr=1e-3)
    labels = _sds(mesh, (b,), jnp.float32, P(dp))
    opt_s = jax.eval_shape(tx.init, params_s)
    state = TrainState(
        step=_sds(mesh, (), jnp.int32, P()),
        params=params,
        opt=_shard_like(mesh, opt_s, RECSYS_RULES),
    )

    def train_step(state_, dense_, sparse_, labels_):
        def loss_fn(params_):
            return bce_loss(params_, cfg, dense_, sparse_, labels_)

        (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(state_.params)
        updates, opt = tx.update(grads, state_.opt, state_.params)
        new_params = apply_updates(state_.params, updates)
        return TrainState(state_.step + 1, new_params, opt), {
            "loss": loss, "accuracy": m["accuracy"],
        }

    return CellProgram(
        arch_id=arch.arch_id, shape_name=cell.name, kind=kind,
        fn=train_step, args=(state, dense, sparse, labels), donate_argnums=(0,),
        static_info={
            "model_flops": 3.0 * _recsys_mlp_flops(cfg) * b,
            "params": cfg.param_count(),
        },
    )


# ------------------------------------------------- contrastive (the paper)
def _contrastive_program(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    bcfg: BertConfig = arch.model_cfg
    p = cell.params
    # §Perf iteration B1 (EXPERIMENTS.md): both towers fit per chip with
    # optimizer state (~3.5 GB fp32), so pure DP — replicated weights, batch
    # over every mesh axis — removes the weight-contraction activation
    # all-reduces that dominated the baseline (12 x 67.5 GiB wire/step).
    # Sharding rules stay selectable: "tp_fsdp" reproduces the baseline.
    # xdev: explicit shard_map over the DP axes instead of single-program
    # GSPMD — required for cfg.shard_banks (each device owns bank_size/D
    # ring slots; batch sharded, weights replicated, collectives by name)
    xdev = p.get("xdev", False)
    shard_banks = bool(p.get("shard_banks", False))
    # loss_comm='ring' streams the bank shards around the DP ring at loss
    # time (O(bank*d/D) transient) instead of all-gathering them; cells opt
    # in via "loss_comm" and step_program validates it needs shard_banks
    loss_comm = p.get("loss_comm", "all_gather")
    if shard_banks and not xdev:
        raise ValueError(
            "cell sets shard_banks without xdev: sharded banks need the "
            "explicit shard_map path (bank leaves sharded by bank_spec); "
            "the single-program GSPMD path would silently replicate them"
        )
    mode = p.get("sharding", "pure_dp")
    if xdev:
        dp = dp_axes(mesh)
        if p["global_batch"] % _axes_size(mesh, dp) or (
            shard_banks and p["bank_size"] % _axes_size(mesh, dp)
        ):
            raise ValueError(
                f"xdev cell needs global_batch ({p['global_batch']}) and a "
                f"sharded bank_size ({p['bank_size']}) divisible by the DP "
                f"axes {dp} (= {_axes_size(mesh, dp)} shards)"
            )
        rules = bank_rules(dp, shard_banks) + [(r".*", P())]
    elif mode == "pure_dp":
        # largest axis prefix that divides the global batch (paper_batch's
        # B=128 < 256 chips: the paper's own geometry deliberately under-
        # fills a pod — remaining ranks replicate)
        dp = _all_axes(mesh)
        while dp and p["global_batch"] % _axes_size(mesh, dp):
            dp = dp[:-1]
        dp = dp or dp_axes(mesh)
        rules = [(r".*", P())]
    else:
        dp = dp_axes(mesh)
        rules = BERT_RULES
    # §Perf iteration B2, generalized into a PrecisionPolicy
    # (core/precision.py): cells select a preset via "precision"; the legacy
    # "bf16_compute" flag (default True) maps to the 'bf16' preset — bf16
    # activations with fp32 master weights, banks and softmax statistics.
    # 'bf16_banks' additionally stores the bank rings in bf16.
    policy = resolve_precision(
        p.get("precision", "bf16" if p.get("bf16_compute", True) else "fp32")
    )
    bcfg = bcfg.with_precision(policy)
    ccfg = ContrastiveConfig(
        # any registered source x strategy composition; cells default to the
        # paper's contaccum but can select e.g. contcache / prebatch_cache
        method=p.get("method", "contaccum"),
        negatives=p.get("negatives"),
        backprop=p.get("backprop"),
        accumulation_steps=p["accum_steps"],
        bank_size=p["bank_size"],
        # 'fused' streams the extended logits block through the Pallas
        # online-softmax kernel (compiled on TPU, interpreter elsewhere)
        loss_impl=p.get("loss_impl", "dense"),
        precision=policy,
        temperature=1.0,
        # xdev: explicit collectives over the named DP axes (shard_map).
        # Otherwise dp_axis=None: single-program semantics; GSPMD derives
        # the cross-device negative all-gathers from the batch sharding.
        dp_axis=dp if xdev else None,
        shard_banks=shard_banks,
        loss_comm=loss_comm,
    )
    enc = make_bert_dual_encoder(bcfg)
    tx = chain(
        clip_by_global_norm(2.0),
        adamw(linear_warmup_linear_decay(2e-5, 1237, 50_000)),
    )
    program = build_step_program(enc, tx, ccfg)
    update = program.update
    if xdev:
        sm, sm_kw = get_shard_map()
        state_spec = contrastive_state_spec(dp, shard_banks)
        batch_spec = RetrievalBatch(
            query=P(dp, None),
            passage_pos=P(dp, None),
            passage_hard=P(dp, None, None),
        )
        update = sm(
            program.update,
            mesh=mesh,
            in_specs=(state_spec, batch_spec),
            out_specs=(state_spec, P()),
            **sm_kw,
        )

    state_s = jax.eval_shape(
        lambda: init_state(jax.random.PRNGKey(0), enc, tx, ccfg)
    )
    state = _shard_like(mesh, state_s, rules)

    b, ql, pl = p["global_batch"], p["q_len"], p["p_len"]
    # mined hard negatives (repro/mining) arrive as extra passage_hard
    # columns injected at batch assembly — to the compiled program they are
    # indistinguishable from corpus-supplied hard negatives, so the cell
    # just widens the column axis
    mined = p.get("mined_negatives", 0)
    h = p["n_hard"] + mined
    batch = RetrievalBatch(
        query=_sds(mesh, (b, ql), jnp.int32, P(dp, None)),
        passage_pos=_sds(mesh, (b, pl), jnp.int32, P(dp, None)),
        passage_hard=_sds(mesh, (b, h, pl), jnp.int32, P(dp, None, None)),
    )

    tokens = b * (ql + pl * (1 + h))
    nq, np_ = program.source.bank_sizes(ccfg)
    bank_shards = _axes_size(mesh, dp) if shard_banks else 1
    bank_bytes_dev = bank_bytes_per_device(
        nq, np_, bcfg.d_model, policy, shards=bank_shards
    )
    if program.strategy.name == "rep_cache":
        # one full-batch similarity matrix regardless of K
        rows, cols, n_mats = b + nq, b * (1 + h) + np_, 1
    else:
        k_eff = 1 if program.strategy.name == "direct" else p["accum_steps"]
        rows, cols, n_mats = b // k_eff + nq, (b // k_eff) * (1 + h) + np_, k_eff
    sim_flops = 2.0 * rows * cols * bcfg.d_model * 3 * n_mats
    return CellProgram(
        arch_id=arch.arch_id, shape_name=cell.name, kind="contrastive",
        fn=update, args=(state, batch), donate_argnums=(0,),
        static_info={
            "model_flops": 6.0 * bcfg.param_count() * tokens + sim_flops,
            "params": 2 * bcfg.param_count(),
            "bank_size": p["bank_size"],
            "accum_steps": p["accum_steps"],
            "method": program.name,
            "negatives": program.source.name,
            "backprop": program.strategy.name,
            "loss_impl": ccfg.loss_impl,
            "precision": policy.name,
            "xdev": xdev,
            "shard_banks": shard_banks,
            "loss_comm": loss_comm,
            "bank_shards": bank_shards,
            "bank_bytes_per_device": float(bank_bytes_dev),
            "mined_negatives": mined,
        },
    )


# --------------------------------------- retrieval serving / eval (the paper)
def _retrieval_program(arch: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    """Inference cells on the Retriever surface (repro/retrieval): query-tower
    encode + exact top-k against a corpus index sharded in contiguous row
    blocks over the DP axes (P(dp) rows — the bank_rules layout applied to
    the serving-side persistent state). Queries stay replicated: the big
    operand (the index) never moves; GSPMD derives the candidate merge.

    ``retrieval_serve`` is the online shape (small coalesced batch),
    ``retrieval_eval`` the offline one (the periodic ANCE-style eval sweep:
    thousands of queries against the full index). Both honor the cell's
    "precision" (index rows in the policy's bank dtype, query reps in
    compute dtype, scores fp32) and "search_impl" (dense blocked-scan vs
    the fused Pallas QK^T + running-top-k kernel)."""
    from repro.retrieval.retriever import RetrieverConfig

    bcfg: BertConfig = arch.model_cfg
    p = cell.params
    dp = dp_axes(mesh)
    policy = resolve_precision(p.get("precision", "bf16_banks"))
    bcfg = bcfg.with_precision(policy)
    rcfg = RetrieverConfig(
        top_k=p["top_k"],
        search_impl=p.get("search_impl", "dense"),
        precision=policy,
    )
    backend = rcfg.resolve_backend()
    enc = make_bert_dual_encoder(bcfg)
    k = p["top_k"]

    def search_step(params, index, row_valid, tokens):
        q = enc.encode_query(params, tokens).astype(policy.compute_dtype)
        scores, ids = backend.topk(q, index, k, col_valid=row_valid)
        return ids, scores

    n_dev = _axes_size(mesh, dp)
    n = _pad_to(p["n_passages"], n_dev)
    q_n, ql, d = p["n_queries"], p["q_len"], bcfg.d_model
    params_s = jax.eval_shape(lambda: enc.init(jax.random.PRNGKey(0)))
    args = (
        _shard_like(mesh, params_s, [(r".*", P())]),
        _sds(mesh, (n, d), policy.bank_dtype, P(dp, None)),
        _sds(mesh, (n,), bool, P(dp)),
        _sds(mesh, (q_n, ql), jnp.int32, P()),
    )
    index_bytes_dev = (n * d * jnp.dtype(policy.bank_dtype).itemsize) // n_dev
    return CellProgram(
        arch_id=arch.arch_id, shape_name=cell.name, kind=cell.kind,
        fn=search_step, args=args, donate_argnums=(),
        static_info={
            # encode is inference (2ND); scoring is one Q x N x d matmul
            "model_flops": 2.0 * bcfg.param_count() * q_n * ql
            + 2.0 * q_n * n * d,
            "params": bcfg.param_count(),
            "top_k": k,
            "search_impl": rcfg.search_impl,
            "precision": policy.name,
            "index_rows": n,
            "index_shards": n_dev,
            "index_bytes_per_device": float(index_bytes_dev),
            "padded": {"n_passages": [p["n_passages"], n]},
        },
    )


# --------------------------------------------------------------- dispatcher
_BUILDERS = {
    "train": _lm_train_program,
    "prefill": _lm_prefill_program,
    "decode": _lm_decode_program,
    "gnn_full": _gnn_program,
    "gnn_minibatch": _gnn_program,
    "gnn_mol": _gnn_program,
    "recsys_train": _recsys_program,
    "recsys_serve": _recsys_program,
    "recsys_retrieval": _recsys_program,
    "contrastive": _contrastive_program,
    "retrieval_serve": _retrieval_program,
    "retrieval_eval": _retrieval_program,
}


def build_cell(arch_id: str, shape_name: str, mesh: Mesh) -> CellProgram:
    arch = get_arch(arch_id)
    if shape_name not in arch.shapes:
        raise KeyError(
            f"{arch_id} has no shape {shape_name!r}; known: {sorted(arch.shapes)}"
        )
    cell = arch.shapes[shape_name]
    return _BUILDERS[cell.kind](arch, cell, mesh)


def list_cells(include_contrastive: bool = True):
    """All (arch, shape) pairs: the assigned 40 plus the paper's own cells."""
    out = []
    for arch_id in list_archs():
        arch = get_arch(arch_id)
        if arch.family == "bert" and not include_contrastive:
            continue
        for shape_name in arch.shapes:
            out.append((arch_id, shape_name))
    return out


def input_specs(arch_id: str, shape_name: str, mesh: Mesh):
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn."""
    return build_cell(arch_id, shape_name, mesh).args
