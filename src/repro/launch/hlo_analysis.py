"""Loop-aware roofline-term extraction from compiled (SPMD-partitioned) HLO.

Why not just ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits each
``while`` body ONCE, so anything under a ``lax.scan`` (layer stacks,
microbatch accumulation, loss chunking — i.e. ~all of the work in this
framework) is undercounted by the trip count, and collective instructions
inside loop bodies are likewise counted once.

XLA:CPU annotates loops with ``backend_config={"known_trip_count":{"n":N}}``,
so we parse the partitioned HLO text into its computation graph, propagate a
multiplier along while/call/fusion edges (while-body edges multiply by the
trip count), and accumulate:

  * flops       — 2 * prod(output dims) * prod(contracting dims) per ``dot``
                  (matmul flops only: elementwise flops are noise at these
                  shapes, and every model here is GEMM-dominated);
  * hbm bytes   — per instruction: operand sizes + result size, at fusion
                  granularity (internals of a fused computation touch no HBM)
                  — the same convention XLA's own bytes-accessed uses;
  * collectives — result bytes and estimated wire bytes per op kind, with
                  replica-group-size-aware ring factors:
        all-gather        : out * (g-1)/g
        all-reduce        : out * 2*(g-1)/g   (reduce-scatter + all-gather)
        reduce-scatter    : out * (g-1)        (input = out * g)
        all-to-all        : out * (g-1)/g
        collective-permute: out                (point-to-point)

The three roofline terms (TPU v5e constants; the parsed numbers describe the
per-device SPMD program, matching the "/ chips" normalization):

  compute    = device_flops / 197e12   [s]
  memory     = device_bytes / 819e9    [s]
  collective = device_wire_bytes / 50e9 [s]
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

# ----------------------------------------------------------------- constants
PEAK_FLOPS_BF16 = 197e12   # TPU v5e per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
# "  %name = <types> opname(" — types may be a tuple "( ... )" whose
# elements carry /*index=N*/ comments (hence [^)]* rather than [^=]*).
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\("
)
_TRIP_RE = re.compile(r'known_trip_count[^}]*"n"\s*:\s*"?(\d+)')
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CALLED_RE = re.compile(
    r"(?:body|condition|calls|to_apply)=%?([\w.\-]+)"
    r"|called_computations=\{([^}]*)\}"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

# shells that do no data work themselves
_FREE_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "while", "call", "conditional", "iota", "partition-id",
    "replica-id", "opt-barrier",
}


def _shapes(types_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _TYPE_RE.finditer(types_str):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((m.group(1), dims))
    return out


def _bytes_of(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, dims in shapes:
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result: List[Tuple[str, Tuple[int, ...]]]
    line: str


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: List[_Instr] = dataclasses.field(default_factory=list)


def _parse_computations(text: str) -> Tuple[Dict[str, _Comp], Optional[str]]:
    comps: Dict[str, _Comp] = {}
    entry: Optional[str] = None
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        if not line:
            continue
        if line[0] not in " }" and "{" in line and ("->" in line or line.startswith("ENTRY")):
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = _Comp(name=m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        cur.instrs.append(
            _Instr(name=m.group(1), op=m.group(3), result=_shapes(m.group(2)), line=line)
        )
    return comps, entry


def _multipliers(comps: Dict[str, _Comp], entry: str) -> Tuple[Dict[str, float], int]:
    """Computation name -> execution-count multiplier (while bodies multiply
    by their known trip count). Returns (multipliers, n_unannotated_loops).

    The HLO computation call graph is a DAG; multipliers accumulate over all
    call paths, so we topologically sort the reachable subgraph (Kahn) and do
    one forward accumulation pass.
    """
    unannotated = 0
    edges: Dict[str, List[Tuple[str, float]]] = {}

    def comp_edges(cname: str) -> List[Tuple[str, float]]:
        nonlocal unannotated
        if cname in edges:
            return edges[cname]
        out: List[Tuple[str, float]] = []
        for ins in comps[cname].instrs:
            if ins.op == "while":
                t = _TRIP_RE.search(ins.line)
                trips = float(t.group(1)) if t else 1.0
                if not t:
                    unannotated += 1
                for m in _CALLED_RE.finditer(ins.line):
                    callee = m.group(1)
                    if callee and callee in comps:
                        out.append((callee, trips))
            else:
                for m in _CALLED_RE.finditer(ins.line):
                    names = [m.group(1)] if m.group(1) else [
                        x.strip().lstrip("%") for x in m.group(2).split(",")
                    ]
                    for callee in names:
                        if callee and callee in comps:
                            out.append((callee, 1.0))
        edges[cname] = out
        return out

    # reachable subgraph + in-degrees
    seen = {entry}
    stack = [entry]
    indeg: Dict[str, int] = defaultdict(int)
    while stack:
        c = stack.pop()
        for callee, _ in comp_edges(c):
            indeg[callee] += 1
            if callee not in seen:
                seen.add(callee)
                stack.append(callee)

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    queue = [entry]
    while queue:
        c = queue.pop()
        for callee, w in comp_edges(c):
            mult[callee] += mult[c] * w
            indeg[callee] -= 1
            if indeg[callee] == 0:
                queue.append(callee)
    return mult, unannotated


def _fusion_called(comps: Dict[str, _Comp]) -> set:
    """Computations reached via fusion/reduce/etc. 'calls='/'to_apply=' whose
    instruction bytes must NOT be double counted (they touch no HBM)."""
    called = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op in ("while", "call", "conditional"):
                continue
            for m in _CALLED_RE.finditer(ins.line):
                names = [m.group(1)] if m.group(1) else [
                    x.strip().lstrip("%") for x in m.group(2).split(",")
                ]
                for n in names:
                    if n:
                        called.add(n)
    return called


def _dot_flops(ins: _Instr, symbols: Dict[str, List[Tuple[str, Tuple[int, ...]]]]) -> float:
    out_elems = 1
    for _, dims in ins.result:
        for d in dims:
            out_elems *= d
    # contracting size from lhs operand shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    paren = ins.line.split("(", 1)[1]
    ops = _OPERAND_RE.findall(paren.split(")", 1)[0])
    k = 1
    if m and ops:
        lhs = symbols.get(ops[0])
        if lhs:
            dims = lhs[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * out_elems * k


@dataclasses.dataclass
class CollectiveStats:
    op: str
    count: float = 0.0
    result_bytes: float = 0.0
    wire_bytes: float = 0.0


def _wire_bytes(op: str, result_bytes: float, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-gather":
        return result_bytes * (g - 1) / g
    if op == "all-reduce":
        return result_bytes * 2.0 * (g - 1) / g
    if op == "reduce-scatter":
        return result_bytes * (g - 1)
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    if op == "collective-permute":
        return float(result_bytes)
    return 0.0


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        if first:
            return max(len(first.split(",")), 1)
    return default


@dataclasses.dataclass
class HloStats:
    flops: float
    hbm_bytes: float
    wire_bytes: float
    collectives: Dict[str, CollectiveStats]
    n_unannotated_loops: int
    n_dots: int
    # top collective contributors: (op, result_type, group, mult, wire_bytes)
    top_collectives: List[tuple] = dataclasses.field(default_factory=list)
    # top HBM-traffic contributors: (op, result_type, mult, bytes)
    top_hbm: List[tuple] = dataclasses.field(default_factory=list)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def analyze_hlo(text: str, n_devices: int) -> HloStats:
    comps, entry = _parse_computations(text)
    if entry is None:  # pragma: no cover
        raise ValueError("no ENTRY computation found in HLO text")
    mult, unannotated = _multipliers(comps, entry)
    fused = _fusion_called(comps)

    # symbol table: instruction name -> result shapes (global; names unique)
    symbols: Dict[str, List[Tuple[str, Tuple[int, ...]]]] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            symbols[ins.name] = ins.result

    flops = 0.0
    hbm = 0.0
    n_dots = 0
    colls: Dict[str, CollectiveStats] = {
        op: CollectiveStats(op=op) for op in COLLECTIVE_OPS
    }
    contributors: List[tuple] = []
    hbm_contrib: List[tuple] = []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        in_fused = comp.name in fused
        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                flops += m * _dot_flops(ins, symbols)
                n_dots += 1
            base_op = op[:-6] if op.endswith("-start") else op
            base_op = base_op[:-5] if base_op.endswith("-done") else base_op
            if base_op in COLLECTIVE_OPS and not op.endswith("-done"):
                rb = _bytes_of(ins.result)
                # async -start returns (operand, result) tuples: halve
                if op.endswith("-start"):
                    rb = rb / 2
                g = _group_size(ins.line, n_devices)
                s = colls[base_op]
                s.count += m
                s.result_bytes += m * rb
                s.wire_bytes += m * _wire_bytes(base_op, rb, g)
                contributors.append(
                    (
                        base_op,
                        "/".join(
                            f"{dt}{list(dims)}" for dt, dims in ins.result
                        )[:96],
                        g,
                        m,
                        m * _wire_bytes(base_op, rb, g),
                    )
                )
            if in_fused or op in _FREE_OPS or op.endswith("-done"):
                continue
            # bytes: operands + result at fusion granularity
            rb = _bytes_of(ins.result)
            ob = 0
            paren = ins.line.split("(", 1)
            if len(paren) > 1:
                for name in _OPERAND_RE.findall(paren[1].split(")", 1)[0]):
                    ob += _bytes_of(symbols.get(name, []))
            hbm += m * (rb + ob)
            hbm_contrib.append(
                (
                    op,
                    "/".join(f"{dt}{list(dims)}" for dt, dims in ins.result)[:96],
                    m,
                    m * (rb + ob),
                )
            )

    wire = sum(s.wire_bytes for s in colls.values())
    contributors.sort(key=lambda c: -c[-1])
    hbm_contrib.sort(key=lambda c: -c[-1])
    return HloStats(
        flops=flops,
        hbm_bytes=hbm,
        wire_bytes=wire,
        collectives={k: v for k, v in colls.items() if v.count},
        n_unannotated_loops=unannotated,
        n_dots=n_dots,
        top_collectives=contributors[:20],
        top_hbm=hbm_contrib[:20],
    )


# ------------------------------------------------------------------ roofline
@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # per-device flops (loop-corrected, dots only)
    hbm_bytes: float             # per-device bytes (loop-corrected)
    wire_bytes: float            # per-device collective wire bytes
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    collectives: Dict[str, dict]
    raw_cost_flops: float = 0.0  # XLA cost_analysis (loop bodies counted once)
    raw_cost_bytes: float = 0.0
    n_unannotated_loops: int = 0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline(stats: HloStats, *, raw_flops: float = 0.0, raw_bytes: float = 0.0) -> RooflineTerms:
    t_c = stats.flops / PEAK_FLOPS_BF16
    t_m = stats.hbm_bytes / HBM_BW
    t_x = stats.wire_bytes / ICI_BW
    dominant = max(
        (("compute", t_c), ("memory", t_m), ("collective", t_x)),
        key=lambda kv: kv[1],
    )[0]
    return RooflineTerms(
        flops=stats.flops,
        hbm_bytes=stats.hbm_bytes,
        wire_bytes=stats.wire_bytes,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        dominant=dominant,
        collectives={
            k: dataclasses.asdict(v) for k, v in stats.collectives.items()
        },
        raw_cost_flops=raw_flops,
        raw_cost_bytes=raw_bytes,
        n_unannotated_loops=stats.n_unannotated_loops,
    )


def cost_numbers(compiled) -> Tuple[float, float]:
    """(flops, bytes_accessed) from compiled.cost_analysis(); loop bodies are
    counted ONCE by XLA — kept for cross-checking only."""
    try:
        ca = compiled.cost_analysis()
    except Exception:  # pragma: no cover
        return 0.0, 0.0
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return flops, byts


def memory_numbers(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:   # pragma: no cover
        return {}
    out = {}
    for name in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        if hasattr(ma, name):
            out[name] = int(getattr(ma, name))
    if out:
        out["total_bytes"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out
