"""Training driver: the paper's dense-retriever training (any of the four
methods) on synthetic or DPR-format data, wired through the fault-tolerant
Trainer. CPU-runnable end to end at reduced scale; the same step functions
lower for the production meshes via launch/dryrun.py.

  PYTHONPATH=src python -m repro.launch.train \
      --method contaccum --total-batch 128 --local-batch 8 --bank 512 \
      --steps 200 --checkpoint-dir /tmp/ckpt

Data-parallel shard_map path (requires >= N devices, e.g.
XLA_FLAGS=--xla_force_host_platform_device_count=8 on CPU): ``--dp N``
shards the batch over an N-way mesh with cross-device in-batch negatives;
``--shard-banks`` additionally gives each device a bank/N shard of the
memory banks instead of replicating them (core/step_program.py);
``--loss-comm ring`` then streams those shards around the DP ring at loss
time instead of all-gathering them (core/loss.py).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train \
      --method contaccum --dp 8 --shard-banks --total-batch 64 --bank 256

Asynchronous hard-negative mining (repro/mining): ``--negatives mined``
spins up a ``HardNegativeMiner`` that periodically re-encodes the corpus
with a snapshot of the training params on a background thread and publishes
per-query hard negatives; the loader joins them into every batch as extra
``passage_hard`` columns. Composes with any --method — with a bank method
(e.g. contaccum) the banks keep extending the matrix *and* every batch
carries mined columns:

  PYTHONPATH=src python -m repro.launch.train \
      --method contaccum --negatives mined --mine-every 50 --mine-topk 32
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dist import get_shard_map
from repro.core.methods import (
    available_methods,
    build_step_program,
    init_state,
    method_composition,
    method_needs_mesh,
    method_uses_banks,
)
from repro.core.precision import PRECISION_PRESETS
from repro.core.types import ContrastiveConfig, RetrievalBatch
from repro.data.loader import ShardedLoader
from repro.data.retrieval import SyntheticRetrievalCorpus
from repro.models.bert import BertConfig
from repro.models.towers import make_bert_dual_encoder
from repro.optim.adamw import adamw, chain, clip_by_global_norm
from repro.optim.schedules import linear_warmup_linear_decay
from repro.runtime.trainer import Trainer, TrainerConfig


def tiny_bert(vocab: int = 1000) -> BertConfig:
    return BertConfig(
        name="bert-tiny",
        n_layers=2,
        d_model=64,
        n_heads=4,
        d_ff=128,
        vocab_size=vocab,
        max_position=64,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    # mesh-requiring compositions can't build in this single-program driver;
    # only offer methods it can actually run
    methods = [m for m in available_methods() if not method_needs_mesh(m)]
    ap.add_argument("--method", default="contaccum", choices=methods)
    ap.add_argument("--loss-impl", default="dense", choices=["dense", "fused"],
                    help="loss backend (core/loss.py): dense einsum or the "
                         "blocked Pallas online-softmax kernel")
    ap.add_argument("--precision", default="fp32",
                    choices=sorted(PRECISION_PRESETS),
                    help="PrecisionPolicy preset (core/precision.py): fp32 "
                         "(reference), bf16 (bf16 compute, fp32 masters/"
                         "banks), bf16_banks (bf16 compute AND bf16 bank "
                         "buffers — halves persistent bank bytes)")
    ap.add_argument("--total-batch", type=int, default=64)
    ap.add_argument("--local-batch", type=int, default=8)
    ap.add_argument("--bank", type=int, default=256)
    ap.add_argument("--dp", type=int, default=0,
                    help="shard_map the update over N data-parallel devices "
                         "(0 = single-program; needs jax.device_count() >= N)")
    ap.add_argument("--shard-banks", action="store_true",
                    help="shard the memory banks over the DP mesh "
                         "(bank/N rows per device) instead of replicating")
    ap.add_argument("--loss-comm", default="all_gather",
                    choices=["all_gather", "ring"],
                    help="how sharded bank columns reach the loss (needs "
                         "--shard-banks): all_gather materializes the full "
                         "(bank, d) block per eval; ring streams one bank/N "
                         "shard at a time around the DP ring via ppermute "
                         "with an online-softmax merge — exact, peak "
                         "transient O(bank*d/N) instead of O(bank*d)")
    ap.add_argument("--negatives", default=None, choices=["mined"],
                    help="override the method's negative source: 'mined' "
                         "runs the asynchronous hard-negative miner "
                         "(repro/mining) and injects its table into every "
                         "batch; bank methods keep their banks on top")
    ap.add_argument("--mine-every", type=int, default=50,
                    help="trainer steps between mining refreshes")
    ap.add_argument("--mine-topk", type=int, default=32,
                    help="mining search depth per query (>= band upper edge)")
    ap.add_argument("--mine-negatives", type=int, default=4,
                    help="mined negatives injected per query per batch")
    ap.add_argument("--mine-band", type=int, nargs=2, default=None,
                    metavar=("LO", "HI"),
                    help="teleportation band [LO, HI) of gold-excluded ranks "
                         "(default [1, mine-topk))")
    ap.add_argument("--mine-margin", type=float, default=0.0,
                    help="drop mined candidates scoring within this margin "
                         "of the gold passage (false-negative guard)")
    ap.add_argument("--mine-sync", action="store_true",
                    help="refresh synchronously on the training thread "
                         "(deterministic; default is the async background "
                         "pipeline)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--corpus-size", type=int, default=2048)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    dp = args.dp
    if args.shard_banks and not dp:
        raise SystemExit("--shard-banks needs --dp N (banks shard over the DP mesh)")
    if args.shard_banks and not method_uses_banks(args.method):
        raise SystemExit(f"--shard-banks: method {args.method!r} has no memory banks")
    if args.loss_comm == "ring" and not args.shard_banks:
        raise SystemExit("--loss-comm ring needs --shard-banks (it streams "
                         "the per-device bank shards around the DP ring)")
    if dp:
        if jax.device_count() < dp:
            raise SystemExit(
                f"--dp {dp} needs >= {dp} devices (have {jax.device_count()}; "
                f"on CPU set XLA_FLAGS=--xla_force_host_platform_device_count={dp})"
            )
        if args.total_batch % dp:
            raise SystemExit(f"--total-batch {args.total_batch} not divisible by --dp {dp}")
        if args.shard_banks and args.bank % dp:
            raise SystemExit(f"--bank {args.bank} not divisible by --dp {dp}")

    source, _ = method_composition(args.method)
    mine = args.negatives == "mined" or source == "mined"
    # with a bank method the banks stay the source and mined columns ride
    # the batch (contaccum x mined); otherwise the source becomes 'mined'
    negatives = (
        "mined" if mine and not method_uses_banks(args.method) else None
    )

    bank = args.bank if method_uses_banks(args.method) else 0
    # with --dp the per-device batch is total/dp; accumulation chunks split
    # the *local* batch so K still targets --local-batch rows per chunk
    k = max(args.total_batch // max(dp, 1) // args.local_batch, 1)
    _, backprop = method_composition(args.method)
    cfg = ContrastiveConfig(
        method=args.method,
        negatives=negatives,
        accumulation_steps=k if backprop != "direct" else 1,
        bank_size=bank,
        loss_impl=args.loss_impl,
        precision=args.precision,
        temperature=1.0,
        grad_clip_norm=2.0,
        dp_axis="data" if dp else None,
        shard_banks=bool(args.shard_banks and dp and bank),
        loss_comm=args.loss_comm,
    )
    enc = make_bert_dual_encoder(tiny_bert(), precision=args.precision)
    tx = chain(
        clip_by_global_norm(cfg.grad_clip_norm),
        adamw(linear_warmup_linear_decay(args.lr, args.steps // 10, args.steps)),
    )
    program = build_step_program(enc, tx, cfg)
    update = program.update
    if dp:
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.core.types import RetrievalBatch as RB
        from repro.distribution.sharding import contrastive_state_spec

        mesh = Mesh(np.array(jax.devices()[:dp]), ("data",))
        sm, sm_kw = get_shard_map()
        state_spec = contrastive_state_spec(("data",), cfg.shard_banks)
        batch_spec = RB(query=P("data"), passage_pos=P("data"), passage_hard=P("data"))
        update = sm(
            update,
            mesh=mesh,
            in_specs=(state_spec, batch_spec),
            out_specs=(state_spec, P()),
            **sm_kw,
        )
    update = jax.jit(update, donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(args.seed), enc, tx, cfg)

    corpus = SyntheticRetrievalCorpus(
        n_passages=args.corpus_size, q_len=16, p_len=32, seed=args.seed
    )
    loader = ShardedLoader(args.corpus_size, args.total_batch, seed=args.seed)

    miner = None
    injector = None
    hooks = []
    if mine:
        from repro.data.loader import MinedNegativeInjector
        from repro.mining import HardNegativeMiner, MinerConfig
        from repro.runtime.trainer import PeriodicHook

        band = args.mine_band or (1, args.mine_topk)
        mcfg = MinerConfig(
            refresh_every=args.mine_every,
            top_k=args.mine_topk,
            n_negatives=args.mine_negatives,
            depth_lo=band[0],
            depth_hi=band[1],
            margin=args.mine_margin,
            sync=args.mine_sync,
            precision=args.precision,
        )
        # corpus alignment: query i's gold passage IS passage i
        miner = HardNegativeMiner(
            enc, mcfg, queries=corpus.queries, passages=corpus.passages
        )
        injector = MinedNegativeInjector(
            miner.buffer.read,
            corpus.n_passages,
            seed=args.seed,
            state=loader.state,
            on_step=miner.note_step,
        )
        hooks.append(
            PeriodicHook(
                every=mcfg.refresh_every,
                fn=miner.refresh_hook,
                prefix="mine/",
                name="mine",
            )
        )

    def next_batch(step):
        idx = loader.next_indices()
        b = corpus.batch(idx)
        hard = b["passage_hard"]
        if injector is not None:
            mined_ids = injector.mined_ids(idx, gold=idx, step=step)
            hard = np.concatenate([hard, corpus.passages[mined_ids]], axis=1)
        return RetrievalBatch(
            query=jnp.asarray(b["query"]),
            passage_pos=jnp.asarray(b["passage_pos"]),
            passage_hard=jnp.asarray(hard),
        )

    trainer = Trainer(
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
        ),
        update,
        next_batch,
        loader_state=loader.state,
        hooks=hooks,
        aux_state=miner,
    )
    state, report = trainer.run(state)
    if miner is not None:
        miner.close()
        print(
            f"mining: {miner.refreshes} refreshes, {miner.skipped} skipped, "
            f"last refresh overlapped {miner.last_overlap} steps"
        )
    print(
        f"done: {report.steps_run} steps, {report.restarts} restarts, "
        f"final loss {report.final_metrics.get('loss', float('nan')):.4f}, "
        f"final grad-norm ratio "
        f"{report.final_metrics.get('grad_norm_ratio', float('nan')):.3f}"
    )
    return state, report


if __name__ == "__main__":
    main()
