import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (including repro.*):
# jax locks the device count at first initialization, and the dry-run needs
# 512 placeholder host devices to build the production meshes.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the single-pod (16,16) and multi-pod (2,16,16) production meshes, and record
memory_analysis / cost_analysis / collective traffic for the roofline report.

Usage:
  python -m repro.launch.dryrun --list
  python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --all --out experiments/dryrun

Each cell writes experiments/dryrun/<mesh>/<arch>__<shape>.json; failures are
recorded with the exception text (a failure here is a sharding bug in the
framework, not an environment problem).
"""

import argparse
import json
import time
import traceback

import jax

from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, list_cells


def run_cell(arch_id: str, shape_name: str, mesh, mesh_name: str, out_dir: str,
             *, verbose: bool = True, extra_tag: str = "") -> dict:
    n_devices = mesh.devices.size
    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": int(n_devices),
        "ok": False,
    }
    t0 = time.time()
    try:
        prog = build_cell(arch_id, shape_name, mesh)
        rec["kind"] = prog.kind
        rec["static_info"] = {
            k: (float(v) if isinstance(v, (int, float)) else v)
            for k, v in prog.static_info.items()
        }
        jitted = jax.jit(prog.fn, donate_argnums=prog.donate_argnums)
        lowered = jitted.lower(*prog.args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()

        raw_flops, raw_bytes = H.cost_numbers(compiled)
        mem = H.memory_numbers(compiled)
        stats = H.analyze_hlo(compiled.as_text(), n_devices)
        roof = H.roofline(stats, raw_flops=raw_flops, raw_bytes=raw_bytes)
        flops = stats.flops

        model_flops = float(prog.static_info.get("model_flops", 0.0))
        global_flops = flops * n_devices
        rec.update(
            ok=True,
            lower_s=round(t_lower - t0, 2),
            compile_s=round(t_compile - t_lower, 2),
            memory=mem,
            roofline=roof.as_dict(),
            top_collectives=stats.top_collectives,
            top_hbm=stats.top_hbm,
            model_flops=model_flops,
            useful_flops_ratio=(
                model_flops / global_flops if global_flops else None
            ),
        )
    except Exception as e:  # a failed cell is a bug; record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 2)

    if out_dir:
        os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
        tag = f"__{extra_tag}" if extra_tag else ""
        path = os.path.join(
            out_dir, mesh_name, f"{arch_id}__{shape_name}{tag}.json"
        )
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        if rec["ok"]:
            r = rec["roofline"]
            mem_gb = rec["memory"].get("total_bytes", 0) / 2**30
            print(
                f"[{mesh_name}] {arch_id}/{shape_name}: OK "
                f"compile={rec['compile_s']}s mem/dev={mem_gb:.2f}GiB "
                f"t_comp={r['t_compute']:.3e}s t_mem={r['t_memory']:.3e}s "
                f"t_coll={r['t_collective']:.3e}s dom={r['dominant']}",
                flush=True,
            )
        else:
            print(
                f"[{mesh_name}] {arch_id}/{shape_name}: FAIL {rec['error']}",
                flush=True,
            )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--only-family", default=None,
                    help="lm|bert|gnn|recsys filter for --all")
    args = ap.parse_args()

    if args.list:
        for a, s in list_cells():
            print(f"{a:24s} {s}")
        return

    cells = (
        list_cells()
        if args.all
        else [(args.arch, args.shape)]
    )
    if args.only_family:
        from repro.configs import get_arch

        cells = [
            (a, s) for a, s in cells if get_arch(a).family == args.only_family
        ]
    if not cells or cells[0][0] is None:
        ap.error("pass --all or --arch/--shape")

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    n_ok = n_fail = 0
    for mesh_name, mesh in meshes:
        for arch_id, shape_name in cells:
            if args.skip_existing:
                path = os.path.join(
                    args.out, mesh_name, f"{arch_id}__{shape_name}.json"
                )
                if os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            continue
            rec = run_cell(arch_id, shape_name, mesh, mesh_name, args.out)
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
