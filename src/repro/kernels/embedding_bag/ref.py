"""Pure-jnp oracle for the embedding-bag kernel: gather + segment_sum."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(
    table: jnp.ndarray,    # (V, D)
    indices: jnp.ndarray,  # (L,) int32 rows to gather
    bag_ids: jnp.ndarray,  # (L,) int32 sorted non-decreasing bag assignment
    n_bags: int,
) -> jnp.ndarray:
    rows = jnp.take(table, indices, axis=0)
    return jax.ops.segment_sum(rows, bag_ids, num_segments=n_bags)
