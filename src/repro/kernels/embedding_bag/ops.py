"""Public embedding-bag API with custom VJP.

Backward: d table = scatter-add of bag cotangents back to gathered rows —
expressed with segment_sum over the (static-size) index list; indices and bag
ids carry no gradient.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_fwd


def _zero_empty(out, bag_ids, n_bags):
    """Bags with no lookups are never visited by the grid — their output
    blocks are undefined on real hardware. Zero them explicitly (TBE
    semantics)."""
    counts = jax.ops.segment_sum(
        jnp.ones_like(bag_ids, jnp.int32), bag_ids, num_segments=n_bags
    )
    return jnp.where((counts > 0)[:, None], out, 0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def embedding_bag(table, indices, bag_ids, n_bags, interpret=True):
    out = embedding_bag_fwd(table, indices, bag_ids, n_bags, interpret=interpret)
    return _zero_empty(out, bag_ids, n_bags)


def _fwd(table, indices, bag_ids, n_bags, interpret):
    out = embedding_bag_fwd(table, indices, bag_ids, n_bags, interpret=interpret)
    return _zero_empty(out, bag_ids, n_bags), (table.shape, indices, bag_ids)


def _bwd(n_bags, interpret, res, g):
    (v, d), indices, bag_ids = res
    # dL/dtable[r] = sum over lookups i with indices[i]==r of g[bag_ids[i]]
    g_rows = jnp.take(g, bag_ids, axis=0)                      # (L, D)
    dtable = jax.ops.segment_sum(g_rows, indices, num_segments=v)
    return dtable, None, None


embedding_bag.defvjp(_fwd, _bwd)
