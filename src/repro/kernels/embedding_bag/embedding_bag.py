"""EmbeddingBag Pallas TPU kernel: ragged gather + bag-sum in one pass.

JAX has no native EmbeddingBag; this is the TPU-native construction using
scalar prefetch: the (sorted-by-bag) index list rides in SMEM ahead of the
grid, and the BlockSpec index_maps *are* the gather — grid step i pulls table
row indices[i] into VMEM and maps the output block to bag_ids[i]. Because
bags are contiguous, revisits of the same output block are consecutive grid
steps, so the kernel accumulates with a first-visit reset (the standard TPU
output-revisit pattern).

One table row per grid step keeps the kernel simple and correct; production
TBE-style batching (multiple rows per step, row blocks) is a documented
§Perf lever. dim is padded to the 128-lane width by the wrapper.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(idx_ref, bag_ref, table_ref, out_ref):
    i = pl.program_id(0)
    first = jnp.logical_or(i == 0, bag_ref[i] != bag_ref[jnp.maximum(i - 1, 0)])
    row = table_ref[0, :]

    @pl.when(first)
    def _set():
        out_ref[0, :] = row

    @pl.when(jnp.logical_not(first))
    def _acc():
        out_ref[0, :] += row


def embedding_bag_fwd(
    table: jnp.ndarray,    # (V, D)
    indices: jnp.ndarray,  # (L,) int32
    bag_ids: jnp.ndarray,  # (L,) int32 sorted non-decreasing
    n_bags: int,
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    v, d = table.shape
    l = indices.shape[0]

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(l,),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, idx, bag: (idx[i], 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, idx, bag: (bag[i], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_bags, d), table.dtype),
        interpret=interpret,
    )(indices.astype(jnp.int32), bag_ids.astype(jnp.int32), table)
