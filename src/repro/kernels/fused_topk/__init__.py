from repro.kernels.fused_topk.ops import fused_topk_scores

__all__ = ["fused_topk_scores"]
