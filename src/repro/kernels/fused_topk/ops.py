"""Jitted public API for the fused top-k scoring kernel.

``fused_topk_scores(q, index, k)`` is the serving analogue of
``fused_infonce_stats``: the (Q, N) score matrix streams tile-by-tile
through VMEM with a per-row running top-k, never materializing in HBM.
Inference-only (no VJP). ``interpret=None`` auto-selects: compiled on TPU,
interpreter elsewhere (CPU-testable), matching FusedLossBackend.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fused_topk.fused_topk import fused_topk


def fused_topk_scores(
    q: jnp.ndarray,
    index: jnp.ndarray,
    k: int,
    *,
    col_valid: Optional[jnp.ndarray] = None,
    inv_tau: float = 1.0,
    block_q: int = 128,
    block_n: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(scores (Q, k) fp32, ids (Q, k) int32; -1 ids mark empty slots)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return fused_topk(
        q, index, k, col_valid=col_valid, inv_tau=inv_tau,
        block_q=block_q, block_n=block_n, interpret=interpret,
    )
