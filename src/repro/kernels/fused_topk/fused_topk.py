"""Blocked QK^T + running top-k Pallas kernel (the serving-side hot loop).

The inference half of the paper's retriever scores every query against the
whole corpus index — a (Q, N) similarity matrix with N in the millions at
production scale. Like the fused InfoNCE kernel this matrix never touches
HBM: the kernel streams (block_q x block_n) tiles through VMEM and folds
each tile into a per-row running top-k scratch (scores + global column ids),
the search-side analogue of fused_infonce's online-softmax accumulator.

Merge semantics per tile: concatenate the (bq, k) running best with the
(bq, bn) fresh tile scores and re-take top_k. The running block sits first in
the concatenation and earlier column blocks were folded earlier, so ties
break toward the lowest column id — exactly ``lax.top_k`` over the full row
(ref.py). Invalid columns (corpus padding, masked shards) are forced to
NEG_INF with id -1, so k > n_valid rows come back with -1-id tail slots
instead of garbage.

Grid layout mirrors fused_infonce_fwd: (Q/bq, N/bn), N innermost so the
top-k scratch carries across column blocks; outputs are written on the last
column step. The contraction dim d is loaded whole per tile (rep_dim <= 8192
fits VMEM). Mixed precision: q/p block loads may be bf16 (the policy's
compute/bank dtypes — a bf16 index halves the tile bytes); every tile matmul
accumulates in fp32 (``preferred_element_type``) and the running scores are
fp32 throughout, so a low-precision index perturbs scores only at input
rounding, never at accumulation.

Inference-only: no VJP — serving never differentiates through search.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.fused_infonce.fused_infonce import (
    NEG_INF,
    _blocking,
    _pad_axis0,
)


def _topk_kernel(valid_ref, q_ref, p_ref, s_out, i_out, s_scr, i_scr,
                 *, inv_tau, k, bn, n_blocks):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = jnp.full_like(s_scr, NEG_INF)
        i_scr[...] = jnp.full_like(i_scr, -1)

    s = jax.lax.dot_general(
        q_ref[...], p_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * inv_tau                                              # (bq, bn)
    vld = valid_ref[pl.ds(j * bn, bn)] != 0
    s = jnp.where(vld[None, :], s, NEG_INF)
    ids = j * bn + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ids = jnp.where(vld[None, :], ids, -1)

    cat_s = jnp.concatenate([s_scr[...], s], axis=1)         # (bq, k + bn)
    cat_i = jnp.concatenate([i_scr[...], ids], axis=1)
    top_s, pos = jax.lax.top_k(cat_s, k)
    s_scr[...] = top_s
    i_scr[...] = jnp.take_along_axis(cat_i, pos, axis=1)

    @pl.when(j == n_blocks - 1)
    def _final():
        s_out[...] = s_scr[...]
        i_out[...] = i_scr[...]


def fused_topk(
    q: jnp.ndarray,                       # (Q, d)
    p: jnp.ndarray,                       # (N, d) corpus index block
    k: int,
    *,
    col_valid: Optional[jnp.ndarray] = None,   # (N,) bool
    inv_tau: float = 1.0,
    block_q: int = 128,
    block_n: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(scores (Q, k) fp32, ids (Q, k) int32); ids are -1 for empty slots.

    Arbitrary Q/N are handled by internal padding (padded rows are sliced
    off, padded columns are marked invalid), matching fused_infonce.
    """
    m, d = q.shape
    n, _ = p.shape
    bq, bn, m_pad, n_pad = _blocking(m, n, block_q, block_n)
    ct = jnp.result_type(q.dtype, p.dtype)
    valid = (
        jnp.ones((n,), jnp.int32)
        if col_valid is None
        else col_valid.astype(jnp.int32)
    )
    q = _pad_axis0(q.astype(ct), m_pad)
    p = _pad_axis0(p.astype(ct), n_pad)
    valid = _pad_axis0(valid, n_pad)
    grid = (m_pad // bq, n_pad // bn)

    kernel = functools.partial(
        _topk_kernel, inv_tau=inv_tau, k=k, bn=bn, n_blocks=grid[1]
    )
    scores, ids = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bq, d), lambda i, j, valid: (i, 0)),
                pl.BlockSpec((bn, d), lambda i, j, valid: (j, 0)),
            ],
            out_specs=[
                pl.BlockSpec((bq, k), lambda i, j, valid: (i, 0)),
                pl.BlockSpec((bq, k), lambda i, j, valid: (i, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((bq, k), jnp.float32),
                pltpu.VMEM((bq, k), jnp.int32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((m_pad, k), jnp.int32),
        ],
        interpret=interpret,
    )(valid, q, p)
    return scores[:m], ids[:m]
