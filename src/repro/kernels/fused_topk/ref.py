"""Dense reference for the fused top-k scoring kernel.

Materializes the full (Q, N) similarity matrix — the thing the fused kernel
exists to avoid — and reduces it with one ``lax.top_k``. Used by the parity
tests and as the semantic contract:

  * scores are fp32 whatever dtype q/p arrive in (the serving counterpart of
    the LossBackend fp32-stats contract);
  * invalid columns (``col_valid`` False) never win a slot;
  * ties break toward the lowest column id (``lax.top_k`` semantics);
  * slots beyond the number of valid columns (k > n_valid) come back with
    score ``NEG_INF`` and id ``-1``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fused_infonce.fused_infonce import NEG_INF


def topk_scores_ref(
    q: jnp.ndarray,                       # (Q, d)
    p: jnp.ndarray,                       # (N, d)
    k: int,
    *,
    col_valid: Optional[jnp.ndarray] = None,   # (N,) bool
    inv_tau: float = 1.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact (scores (Q, k) fp32, ids (Q, k) int32) by full materialization."""
    n = p.shape[0]
    ct = jnp.result_type(q.dtype, p.dtype)
    s = jax.lax.dot_general(
        q.astype(ct), p.astype(ct), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * inv_tau
    if col_valid is not None:
        s = jnp.where(col_valid[None, :], s, NEG_INF)
    if k > n:  # pad columns so top_k is well-defined, mark them invalid
        s = jnp.pad(s, ((0, 0), (0, k - n)), constant_values=NEG_INF)
    scores, ids = jax.lax.top_k(s, k)
    ids = jnp.where(scores > NEG_INF / 2, ids.astype(jnp.int32), -1)
    return scores, ids
