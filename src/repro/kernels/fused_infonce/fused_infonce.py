"""Blocked InfoNCE Pallas TPU kernels (the paper's softmax-cost hot spot).

The (M, N) similarity matrix of ContAccum's extended batch
(M, N ~ N_local + N_memory, up to 128k columns at pod scale) never touches
HBM: the forward kernel streams (block_m x block_n) tiles through VMEM with
an online-softmax accumulator (running max / sum-exp scratch), extracting the
positive logit when the row's label falls inside the current column block.
The backward kernels recompute tiles and emit dQ / dP with the same blocking.

Grid layout (fwd, dq): (M/bm, N/bn), N innermost so per-row scratch carries
across column blocks; output rows are revisited — final values written on the
last column step. dp uses the transposed grid (N/bn, M/bm).

MXU alignment: block_m/block_n default 128 (fp32 lane width 8x128; the matmul
tiles are 128x128). d (the contraction dim) is loaded whole per tile —
rep_dim <= 8192 fits VMEM comfortably (128 x 8192 x 4B = 4 MiB per operand).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(labels_ref, q_ref, p_ref, lse_ref, pos_ref, m_scr, l_scr, *, inv_tau, bm, bn, n_blocks):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        pos_ref[...] = jnp.zeros_like(pos_ref)

    s = jax.lax.dot_general(
        q_ref[...],
        p_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * inv_tau  # (bm, bn)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.exp(s - m_new[:, None]).sum(axis=-1)
    m_scr[...] = m_new

    # positive logit: label inside this column block?
    # (scalar-prefetch operands arrive unblocked: slice this row block)
    lbl = labels_ref[pl.ds(i * bm, bm)]
    col0 = j * bn
    local = lbl - col0
    in_blk = (local >= 0) & (local < bn)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) == local[:, None]
    ).astype(jnp.float32)
    pos_j = (s * onehot).sum(axis=-1)
    pos_ref[...] = jnp.where(in_blk, pos_j, pos_ref[...])

    @pl.when(j == n_blocks - 1)
    def _final():
        lse_ref[...] = m_scr[...] + jnp.log(l_scr[...])


def fused_infonce_fwd(
    q: jnp.ndarray,
    p: jnp.ndarray,
    labels: jnp.ndarray,
    *,
    inv_tau: float = 1.0,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
):
    """Returns (lse, pos) per row; loss = mean(lse - pos)."""
    m, d = q.shape
    n, _ = p.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    assert m % block_m == 0 and n % block_n == 0, (m, block_m, n, block_n)
    grid = (m // block_m, n // block_n)

    kernel = functools.partial(
        _fwd_kernel, inv_tau=inv_tau, bm=block_m, bn=block_n, n_blocks=grid[1]
    )
    lse, pos = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, d), lambda i, j, labels: (i, 0)),
                pl.BlockSpec((block_n, d), lambda i, j, labels: (j, 0)),
            ],
            out_specs=[
                pl.BlockSpec((block_m,), lambda i, j, labels: (i,)),
                pl.BlockSpec((block_m,), lambda i, j, labels: (i,)),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_m,), jnp.float32),
                pltpu.VMEM((block_m,), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=interpret,
    )(labels.astype(jnp.int32), q, p)
    return lse, pos


def _coeff(s, lse_rows, labels, col0, bn, g_lse, g_pos):
    """Per-tile cotangent of the logits: prob * g_lse + onehot * g_pos."""
    prob = jnp.exp(s - lse_rows[:, None])
    local = labels - col0
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) == local[:, None]
    ).astype(jnp.float32)
    return prob * g_lse[:, None] + onehot * g_pos[:, None]


def _dq_kernel(labels_ref, q_ref, p_ref, lse_ref, glse_ref, gpos_ref, dq_ref, *, inv_tau, bm, bn):
    """dQ = sum over column blocks of coeff @ P * inv_tau."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    s = jax.lax.dot_general(
        q_ref[...], p_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * inv_tau
    coeff = _coeff(s, lse_ref[...], labels_ref[pl.ds(i * bm, bm)], j * bn, bn,
                   glse_ref[...], gpos_ref[...]) * inv_tau
    dq_ref[...] += jax.lax.dot_general(
        coeff.astype(p_ref.dtype), p_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(dq_ref.dtype)


def _dp_kernel(labels_ref, q_ref, p_ref, lse_ref, glse_ref, gpos_ref, dp_ref, *, inv_tau, bm, bn):
    """dP = sum over row blocks of coeff^T @ Q * inv_tau.
    Grid: (N/bn, M/bm) — column blocks outer, row blocks inner (accumulated)."""
    i = pl.program_id(1)
    j = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dp_ref[...] = jnp.zeros_like(dp_ref)

    s = jax.lax.dot_general(
        q_ref[...], p_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * inv_tau  # (bm, bn)
    coeff = _coeff(s, lse_ref[...], labels_ref[pl.ds(i * bm, bm)], j * bn, bn,
                   glse_ref[...], gpos_ref[...]) * inv_tau
    dp_ref[...] += jax.lax.dot_general(
        coeff.astype(q_ref.dtype), q_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(dp_ref.dtype)


def fused_infonce_bwd(
    q, p, labels, lse, g_lse, g_pos,
    *,
    inv_tau: float = 1.0,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
):
    """Exact VJP given the per-row cotangents of (lse, pos)."""
    m, d = q.shape
    n, _ = p.shape
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    grid_q = (m // block_m, n // block_n)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, inv_tau=inv_tau, bm=block_m, bn=block_n),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid_q,
            in_specs=[
                pl.BlockSpec((block_m, d), lambda i, j, labels: (i, 0)),
                pl.BlockSpec((block_n, d), lambda i, j, labels: (j, 0)),
                pl.BlockSpec((block_m,), lambda i, j, labels: (i,)),
                pl.BlockSpec((block_m,), lambda i, j, labels: (i,)),
                pl.BlockSpec((block_m,), lambda i, j, labels: (i,)),
            ],
            out_specs=pl.BlockSpec((block_m, d), lambda i, j, labels: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.float32),
        interpret=interpret,
    )(labels.astype(jnp.int32), q, p, lse, g_lse, g_pos)

    grid_p = (n // block_n, m // block_m)
    dp = pl.pallas_call(
        functools.partial(_dp_kernel, inv_tau=inv_tau, bm=block_m, bn=block_n),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid_p,
            in_specs=[
                pl.BlockSpec((block_m, d), lambda j, i, labels: (i, 0)),
                pl.BlockSpec((block_n, d), lambda j, i, labels: (j, 0)),
                pl.BlockSpec((block_m,), lambda j, i, labels: (i,)),
                pl.BlockSpec((block_m,), lambda j, i, labels: (i,)),
                pl.BlockSpec((block_m,), lambda j, i, labels: (i,)),
            ],
            out_specs=pl.BlockSpec((block_n, d), lambda j, i, labels: (j, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(labels.astype(jnp.int32), q, p, lse, g_lse, g_pos)

    return dq.astype(q.dtype), dp.astype(p.dtype)
