"""Blocked InfoNCE Pallas TPU kernels (the paper's softmax-cost hot spot).

The (M, N) similarity matrix of ContAccum's extended batch
(M, N ~ N_local + N_memory, up to 128k columns at pod scale) never touches
HBM: the forward kernel streams (block_m x block_n) tiles through VMEM with
an online-softmax accumulator (running max / sum-exp scratch), extracting the
positive logit when the row's label falls inside the current column block.
The backward kernels recompute tiles and emit dQ / dP with the same blocking.

Bank-layout support (what core/loss.py's extended matrix needs):
  * ``col_valid`` — per-column validity; invalid columns (bank warm-up slots,
    padding) are masked to NEG_INF inside every tile, so they contribute
    neither to the softmax nor to the gradients (the backward coefficient is
    zeroed for masked columns, matching the dense ``jnp.where`` whose
    gradient w.r.t. a masked logit is exactly zero).
  * ragged M/N — inputs are padded internally to the block grid (padded rows
    are dropped from the outputs, padded columns are masked invalid), so
    batch/bank sizes need not be multiples of the 128-lane MXU tile.
  * ``amax`` output — the per-row running maximum, so callers can derive
    argmax-accuracy (``pos >= amax``) without a second pass.

Grid layout (fwd, dq): (M/bm, N/bn), N innermost so per-row scratch carries
across column blocks; output rows are revisited — final values written on the
last column step. dp uses the transposed grid (N/bn, M/bm).

MXU alignment: block_m/block_n default 128 (fp32 lane width 8x128; the matmul
tiles are 128x128). d (the contraction dim) is loaded whole per tile —
rep_dim <= 8192 fits VMEM comfortably (128 x 8192 x 4B = 4 MiB per operand).

Mixed precision (core/precision.py): q/p block loads may be bf16 (the
policy's compute dtype — halves the VMEM per operand tile and feeds the MXU
its native input width). Mismatched q/p dtypes are reconciled to a common
compute dtype at the entry points below; every tile matmul accumulates in
fp32 (``preferred_element_type``), the online-softmax scratch, lse/pos/amax
outputs and backward coefficients are fp32 throughout (the policy's
``accum_dtype``), and dQ/dP are accumulated in fp32 before a final cast back
to the input dtype — low-precision inputs never degrade the statistics or
the VJP accumulation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(labels_ref, valid_ref, q_ref, p_ref, lse_ref, pos_ref, amax_ref,
                m_scr, l_scr, *, inv_tau, bm, bn, n_blocks):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        pos_ref[...] = jnp.zeros_like(pos_ref)

    s = jax.lax.dot_general(
        q_ref[...],
        p_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * inv_tau  # (bm, bn)
    # invalid columns never enter the softmax (bank warm-up slots, padding)
    vld = valid_ref[pl.ds(j * bn, bn)] != 0
    s = jnp.where(vld[None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.exp(s - m_new[:, None]).sum(axis=-1)
    m_scr[...] = m_new

    # positive logit: label inside this column block?
    # (scalar-prefetch operands arrive unblocked: slice this row block)
    lbl = labels_ref[pl.ds(i * bm, bm)]
    col0 = j * bn
    local = lbl - col0
    in_blk = (local >= 0) & (local < bn)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) == local[:, None]
    ).astype(jnp.float32)
    pos_j = (s * onehot).sum(axis=-1)
    pos_ref[...] = jnp.where(in_blk, pos_j, pos_ref[...])

    @pl.when(j == n_blocks - 1)
    def _final():
        lse_ref[...] = m_scr[...] + jnp.log(l_scr[...])
        amax_ref[...] = m_scr[...]


def _pad_axis0(x: jnp.ndarray, to: int, fill=0):
    n = x.shape[0]
    if n == to:
        return x
    pad = [(0, to - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad, constant_values=fill)


def _blocking(m: int, n: int, block_m: int, block_n: int):
    """Effective block sizes + padded sizes: blocks are clipped to the array,
    then the array is padded up to a whole number of blocks (ragged shapes)."""
    bm = min(block_m, m)
    bn = min(block_n, n)
    m_pad = -(-m // bm) * bm
    n_pad = -(-n // bn) * bn
    return bm, bn, m_pad, n_pad


def _prep_operands(q, p, labels, col_valid, m_pad, n_pad):
    """Pad to the block grid: padded rows are zeros (outputs sliced off),
    padded columns are marked invalid (masked to NEG_INF in-kernel). q/p are
    reconciled to a common compute dtype (dtype-aware block loads: bf16
    stays bf16, mixed bf16/fp32 inputs promote to fp32) — the in-kernel
    matmuls accumulate in fp32 regardless."""
    n = p.shape[0]
    ct = jnp.result_type(q.dtype, p.dtype)
    valid = (
        jnp.ones((n,), jnp.int32)
        if col_valid is None
        else col_valid.astype(jnp.int32)
    )
    return (
        _pad_axis0(q.astype(ct), m_pad),
        _pad_axis0(p.astype(ct), n_pad),
        _pad_axis0(labels.astype(jnp.int32), m_pad),
        _pad_axis0(valid, n_pad),
    )


def fused_infonce_fwd(
    q: jnp.ndarray,
    p: jnp.ndarray,
    labels: jnp.ndarray,
    *,
    col_valid: Optional[jnp.ndarray] = None,
    inv_tau: float = 1.0,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
):
    """Returns (lse, pos, amax) per row; loss = mean(lse - pos).

    ``col_valid`` (N,) masks columns exactly (None = all valid); arbitrary
    M/N are handled by internal padding.
    """
    m, d = q.shape
    n, _ = p.shape
    bm, bn, m_pad, n_pad = _blocking(m, n, block_m, block_n)
    q, p, labels, valid = _prep_operands(q, p, labels, col_valid, m_pad, n_pad)
    grid = (m_pad // bm, n_pad // bn)

    kernel = functools.partial(
        _fwd_kernel, inv_tau=inv_tau, bm=bm, bn=bn, n_blocks=grid[1]
    )
    lse, pos, amax = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, d), lambda i, j, labels, valid: (i, 0)),
                pl.BlockSpec((bn, d), lambda i, j, labels, valid: (j, 0)),
            ],
            out_specs=[
                pl.BlockSpec((bm,), lambda i, j, labels, valid: (i,)),
                pl.BlockSpec((bm,), lambda i, j, labels, valid: (i,)),
                pl.BlockSpec((bm,), lambda i, j, labels, valid: (i,)),
            ],
            scratch_shapes=[
                pltpu.VMEM((bm,), jnp.float32),
                pltpu.VMEM((bm,), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((m_pad,), jnp.float32),
            jax.ShapeDtypeStruct((m_pad,), jnp.float32),
            jax.ShapeDtypeStruct((m_pad,), jnp.float32),
        ],
        interpret=interpret,
    )(labels, valid, q, p)
    return lse[:m], pos[:m], amax[:m]


def _coeff(s, vld, lse_rows, labels, col0, bn, g_lse, g_pos):
    """Per-tile cotangent of the logits: prob * g_lse + onehot * g_pos.
    Zero for invalid columns — the dense path's ``where`` mask has exactly
    zero gradient w.r.t. a masked logit."""
    prob = jnp.exp(s - lse_rows[:, None])
    local = labels - col0
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) == local[:, None]
    ).astype(jnp.float32)
    coeff = prob * g_lse[:, None] + onehot * g_pos[:, None]
    return jnp.where(vld[None, :], coeff, 0.0)


def _dq_kernel(labels_ref, valid_ref, q_ref, p_ref, lse_ref, glse_ref, gpos_ref,
               dq_ref, *, inv_tau, bm, bn):
    """dQ = sum over column blocks of coeff @ P * inv_tau."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    s = jax.lax.dot_general(
        q_ref[...], p_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * inv_tau
    vld = valid_ref[pl.ds(j * bn, bn)] != 0
    s = jnp.where(vld[None, :], s, NEG_INF)
    coeff = _coeff(s, vld, lse_ref[...], labels_ref[pl.ds(i * bm, bm)], j * bn,
                   bn, glse_ref[...], gpos_ref[...]) * inv_tau
    dq_ref[...] += jax.lax.dot_general(
        coeff.astype(p_ref.dtype), p_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(dq_ref.dtype)


def _dp_kernel(labels_ref, valid_ref, q_ref, p_ref, lse_ref, glse_ref, gpos_ref,
               dp_ref, *, inv_tau, bm, bn):
    """dP = sum over row blocks of coeff^T @ Q * inv_tau.
    Grid: (N/bn, M/bm) — column blocks outer, row blocks inner (accumulated)."""
    i = pl.program_id(1)
    j = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dp_ref[...] = jnp.zeros_like(dp_ref)

    s = jax.lax.dot_general(
        q_ref[...], p_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * inv_tau  # (bm, bn)
    vld = valid_ref[pl.ds(j * bn, bn)] != 0
    s = jnp.where(vld[None, :], s, NEG_INF)
    coeff = _coeff(s, vld, lse_ref[...], labels_ref[pl.ds(i * bm, bm)], j * bn,
                   bn, glse_ref[...], gpos_ref[...]) * inv_tau
    dp_ref[...] += jax.lax.dot_general(
        coeff.astype(q_ref.dtype), q_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(dp_ref.dtype)


def fused_infonce_bwd(
    q, p, labels, lse, g_lse, g_pos,
    *,
    col_valid: Optional[jnp.ndarray] = None,
    inv_tau: float = 1.0,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = False,
):
    """Exact VJP given the per-row cotangents of (lse, pos)."""
    m, d = q.shape
    n, _ = p.shape
    bm, bn, m_pad, n_pad = _blocking(m, n, block_m, block_n)
    q, p, labels, valid = _prep_operands(q, p, labels, col_valid, m_pad, n_pad)
    # padded rows carry zero cotangents and lse=0, so their uniform
    # exp(0 - 0) probabilities never reach dQ/dP. Statistics and cotangents
    # are fp32 in-kernel whatever dtype q/p arrive in (accum_dtype contract).
    lse = _pad_axis0(lse.astype(jnp.float32), m_pad)
    g_lse = _pad_axis0(g_lse.astype(jnp.float32), m_pad)
    g_pos = _pad_axis0(g_pos.astype(jnp.float32), m_pad)
    grid_q = (m_pad // bm, n_pad // bn)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, inv_tau=inv_tau, bm=bm, bn=bn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid_q,
            in_specs=[
                pl.BlockSpec((bm, d), lambda i, j, labels, valid: (i, 0)),
                pl.BlockSpec((bn, d), lambda i, j, labels, valid: (j, 0)),
                pl.BlockSpec((bm,), lambda i, j, labels, valid: (i,)),
                pl.BlockSpec((bm,), lambda i, j, labels, valid: (i,)),
                pl.BlockSpec((bm,), lambda i, j, labels, valid: (i,)),
            ],
            out_specs=pl.BlockSpec((bm, d), lambda i, j, labels, valid: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((m_pad, d), jnp.float32),
        interpret=interpret,
    )(labels, valid, q, p, lse, g_lse, g_pos)

    grid_p = (n_pad // bn, m_pad // bm)
    dp = pl.pallas_call(
        functools.partial(_dp_kernel, inv_tau=inv_tau, bm=bm, bn=bn),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid_p,
            in_specs=[
                pl.BlockSpec((bm, d), lambda j, i, labels, valid: (i, 0)),
                pl.BlockSpec((bn, d), lambda j, i, labels, valid: (j, 0)),
                pl.BlockSpec((bm,), lambda j, i, labels, valid: (i,)),
                pl.BlockSpec((bm,), lambda j, i, labels, valid: (i,)),
                pl.BlockSpec((bm,), lambda j, i, labels, valid: (i,)),
            ],
            out_specs=pl.BlockSpec((bn, d), lambda j, i, labels, valid: (j, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=interpret,
    )(labels, valid, q, p, lse, g_lse, g_pos)

    return dq[:m].astype(q.dtype), dp[:n].astype(p.dtype)
