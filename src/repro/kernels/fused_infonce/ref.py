"""Pure-jnp oracle for the fused InfoNCE kernel.

Returns per-row (lse, pos_logit); loss = mean(lse - pos). Materializes the
full (M, N) similarity matrix — exactly what the kernel avoids.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def infonce_rows_ref(q: jnp.ndarray, p: jnp.ndarray, labels: jnp.ndarray, *, inv_tau: float = 1.0):
    logits = (
        jnp.einsum("md,nd->mn", q, p, preferred_element_type=jnp.float32) * inv_tau
    )
    lse = jax.nn.logsumexp(logits, axis=-1)
    pos = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return lse, pos


def infonce_stats_ref(
    q: jnp.ndarray,
    p: jnp.ndarray,
    labels: jnp.ndarray,
    col_valid: Optional[jnp.ndarray] = None,
    *,
    inv_tau: float = 1.0,
):
    """Dense oracle for fused_infonce_stats: (lse, pos, amax) with invalid
    columns masked to NEG_INF (gradient exactly zero through the mask)."""
    logits = (
        jnp.einsum("md,nd->mn", q, p, preferred_element_type=jnp.float32) * inv_tau
    )
    if col_valid is not None:
        logits = jnp.where(col_valid[None, :], logits, NEG_INF)
    lse = jax.nn.logsumexp(logits, axis=-1)
    pos = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return lse, pos, jnp.max(logits, axis=-1)


def infonce_loss_ref(q, p, labels, *, inv_tau: float = 1.0):
    lse, pos = infonce_rows_ref(q, p, labels, inv_tau=inv_tau)
    return jnp.mean(lse - pos)


def infonce_grads_ref(q, p, labels, *, inv_tau: float = 1.0):
    return jax.grad(
        lambda q_, p_: infonce_loss_ref(q_, p_, labels, inv_tau=inv_tau), argnums=(0, 1)
    )(q, p)
