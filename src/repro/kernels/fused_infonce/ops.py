"""Jitted public API for the fused InfoNCE kernel with a custom VJP.

``fused_infonce_loss(q, p, labels)`` = mean_i (lse_i - pos_i), computed
without materializing the (M, N) similarity matrix in either direction.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fused_infonce.fused_infonce import (
    fused_infonce_bwd,
    fused_infonce_fwd,
)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def fused_infonce_rows(q, p, labels, inv_tau=1.0, block_m=128, block_n=128, interpret=True):
    """(lse, pos) per row. Differentiable w.r.t. q and p."""
    return fused_infonce_fwd(
        q, p, labels, inv_tau=inv_tau, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )


def _rows_fwd(q, p, labels, inv_tau, block_m, block_n, interpret):
    lse, pos = fused_infonce_fwd(
        q, p, labels, inv_tau=inv_tau, block_m=block_m, block_n=block_n,
        interpret=interpret,
    )
    return (lse, pos), (q, p, labels, lse)


def _rows_bwd(inv_tau, block_m, block_n, interpret, res, cotangents):
    q, p, labels, lse = res
    g_lse, g_pos = cotangents
    dq, dp = fused_infonce_bwd(
        q, p, labels, lse, g_lse, g_pos,
        inv_tau=inv_tau, block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return dq, dp, None


fused_infonce_rows.defvjp(_rows_fwd, _rows_bwd)


def fused_infonce_loss(
    q: jnp.ndarray,
    p: jnp.ndarray,
    labels: Optional[jnp.ndarray] = None,
    *,
    temperature: float = 1.0,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
):
    """Mean InfoNCE over rows. ``interpret=True`` runs the kernel body on CPU
    (this container); on TPU pass interpret=False."""
    if labels is None:
        labels = jnp.arange(q.shape[0], dtype=jnp.int32)
    lse, pos = fused_infonce_rows(
        q, p, labels, 1.0 / temperature, block_m, block_n, interpret
    )
    return jnp.mean(lse - pos)
