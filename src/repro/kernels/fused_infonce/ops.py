"""Jitted public API for the fused InfoNCE kernel with a custom VJP.

``fused_infonce_stats(q, p, labels, col_valid)`` returns per-row
``(lse, pos, amax)`` — everything the loss backend in core/loss.py needs:
``loss = mean(lse - pos)`` (or any per-row weighting, the VJP takes arbitrary
row cotangents) and ``pos >= amax`` recovers argmax-accuracy. None of it
materializes the (M, N) similarity matrix in either direction.

``amax`` is a metrics-only output: its cotangent is discarded by the VJP, so
callers must wrap any use of it in ``jax.lax.stop_gradient``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fused_infonce.fused_infonce import (
    fused_infonce_bwd,
    fused_infonce_fwd,
)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def fused_infonce_stats(q, p, labels, col_valid, inv_tau=1.0, block_m=128,
                        block_n=128, interpret=True):
    """(lse, pos, amax) per row. Differentiable w.r.t. q and p; ``col_valid``
    ((N,) bool or None) masks columns out of the softmax and the gradients."""
    return fused_infonce_fwd(
        q, p, labels, col_valid=col_valid, inv_tau=inv_tau,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )


def _stats_fwd(q, p, labels, col_valid, inv_tau, block_m, block_n, interpret):
    lse, pos, amax = fused_infonce_stats(
        q, p, labels, col_valid, inv_tau, block_m, block_n, interpret
    )
    return (lse, pos, amax), (q, p, labels, col_valid, lse)


def _stats_bwd(inv_tau, block_m, block_n, interpret, res, cotangents):
    q, p, labels, col_valid, lse = res
    g_lse, g_pos, _ = cotangents  # amax is metrics-only: cotangent discarded
    dq, dp = fused_infonce_bwd(
        q, p, labels, lse, g_lse, g_pos, col_valid=col_valid,
        inv_tau=inv_tau, block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return dq, dp, None, None


fused_infonce_stats.defvjp(_stats_fwd, _stats_bwd)


def fused_infonce_rows(q, p, labels, inv_tau=1.0, block_m=128, block_n=128,
                       interpret=True):
    """(lse, pos) per row, all columns valid. Differentiable w.r.t. q and p."""
    lse, pos, _ = fused_infonce_stats(
        q, p, labels, None, inv_tau, block_m, block_n, interpret
    )
    return lse, pos


def fused_infonce_loss(
    q: jnp.ndarray,
    p: jnp.ndarray,
    labels: Optional[jnp.ndarray] = None,
    *,
    col_valid: Optional[jnp.ndarray] = None,
    temperature: float = 1.0,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
):
    """Mean InfoNCE over rows. ``interpret=True`` runs the kernel body on CPU
    (this container); on TPU pass interpret=False."""
    if labels is None:
        labels = jnp.arange(q.shape[0], dtype=jnp.int32)
    lse, pos, _ = fused_infonce_stats(
        q, p, labels, col_valid, 1.0 / temperature, block_m, block_n, interpret
    )
    return jnp.mean(lse - pos)
