"""Jitted public API for the fused InfoNCE kernel with a custom VJP.

``fused_infonce_stats(q, p, labels, col_valid)`` returns per-row
``(lse, pos, amax)`` — everything the loss backend in core/loss.py needs:
``loss = mean(lse - pos)`` (or any per-row weighting, the VJP takes arbitrary
row cotangents) and ``pos >= amax`` recovers argmax-accuracy. None of it
materializes the (M, N) similarity matrix in either direction.

``amax`` is a metrics-only output: its cotangent is discarded by the VJP, so
callers must wrap any use of it in ``jax.lax.stop_gradient``.

The per-row ``(lse, pos, amax)`` triple is also the kernel's *carried
online-softmax state*: ``lse`` is the sufficient statistic of the running
(max, sum-exp) pair the kernel maintains across column tiles, so stats
computed over disjoint column chunks (e.g. one memory-bank shard at a time
as it streams around a device ring) compose into the stats of the full
column set with ``merge_row_stats`` — exactly, not approximately. The
gradients compose too: differentiating through the merge scales each chunk's
``g_lse`` cotangent by ``exp(lse_chunk - lse_global)``, which turns every
chunk-local softmax coefficient ``exp(s - lse_chunk)`` into the *global*
coefficient ``exp(s - lse_global)`` inside the chunk's custom VJP — so dQ
accumulates across chunk calls and each chunk's dP stays exact without the
(M, N_total) matrix ever existing on one device.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.fused_infonce.fused_infonce import (
    fused_infonce_bwd,
    fused_infonce_fwd,
)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def fused_infonce_stats(q, p, labels, col_valid, inv_tau=1.0, block_m=128,
                        block_n=128, interpret=True):
    """(lse, pos, amax) per row. Differentiable w.r.t. q and p; ``col_valid``
    ((N,) bool or None) masks columns out of the softmax and the gradients."""
    return fused_infonce_fwd(
        q, p, labels, col_valid=col_valid, inv_tau=inv_tau,
        block_m=block_m, block_n=block_n, interpret=interpret,
    )


def _stats_fwd(q, p, labels, col_valid, inv_tau, block_m, block_n, interpret):
    lse, pos, amax = fused_infonce_stats(
        q, p, labels, col_valid, inv_tau, block_m, block_n, interpret
    )
    return (lse, pos, amax), (q, p, labels, col_valid, lse)


def _stats_bwd(inv_tau, block_m, block_n, interpret, res, cotangents):
    q, p, labels, col_valid, lse = res
    g_lse, g_pos, _ = cotangents  # amax is metrics-only: cotangent discarded
    dq, dp = fused_infonce_bwd(
        q, p, labels, lse, g_lse, g_pos, col_valid=col_valid,
        inv_tau=inv_tau, block_m=block_m, block_n=block_n, interpret=interpret,
    )
    return dq, dp, None, None


fused_infonce_stats.defvjp(_stats_fwd, _stats_bwd)


def merge_row_stats(lse_chunks, pos_chunks, owns_chunks, amax_chunks):
    """Compose per-chunk row statistics over a *partition* of the column set
    into the statistics of the full set.

    Args (all stacked along a leading chunk axis, shapes (C, M)):
      lse_chunks:  per-chunk ``logsumexp`` rows — the carried softmax state.
      pos_chunks:  per-chunk positive logits; only the owning chunk's value
                   is read (non-owners may carry anything).
      owns_chunks: bool — True where the row's positive column lies inside
                   that chunk. Each row must be owned by exactly one chunk.
      amax_chunks: per-chunk running row maxima (metrics-only, like ``amax``).

    Returns (lse, pos, amax) over the union of the chunks' columns. The merge
    is the online-softmax combine in lse form:
    ``lse = log sum_k exp(lse_k)`` — exact because ``exp(lse_k)`` is chunk
    k's sum of ``exp(s)``. Differentiable in ``lse_chunks``/``pos_chunks``
    (the chain rule routes ``exp(lse_k - lse)`` back to chunk k, and the pos
    cotangent to the owning chunk only); ``amax`` stays metrics-only.

    Chunks with zero valid columns are safe: their logits are masked to the
    finite ``NEG_INF`` (-1e30) sentinel, so their ``exp(lse_k - lse)`` weight
    underflows to exactly 0 rather than producing NaNs.
    """
    lse = jax.nn.logsumexp(lse_chunks, axis=0)
    pos = jnp.sum(jnp.where(owns_chunks, pos_chunks, 0.0), axis=0)
    amax = jnp.max(amax_chunks, axis=0)
    return lse, pos, amax


def fused_infonce_rows(q, p, labels, inv_tau=1.0, block_m=128, block_n=128,
                       interpret=True):
    """(lse, pos) per row, all columns valid. Differentiable w.r.t. q and p."""
    lse, pos, _ = fused_infonce_stats(
        q, p, labels, None, inv_tau, block_m, block_n, interpret
    )
    return lse, pos


def fused_infonce_loss(
    q: jnp.ndarray,
    p: jnp.ndarray,
    labels: Optional[jnp.ndarray] = None,
    *,
    col_valid: Optional[jnp.ndarray] = None,
    temperature: float = 1.0,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
):
    """Mean InfoNCE over rows. ``interpret=True`` runs the kernel body on CPU
    (this container); on TPU pass interpret=False."""
    if labels is None:
        labels = jnp.arange(q.shape[0], dtype=jnp.int32)
    lse, pos, _ = fused_infonce_stats(
        q, p, labels, col_valid, 1.0 / temperature, block_m, block_n, interpret
    )
    return jnp.mean(lse - pos)
