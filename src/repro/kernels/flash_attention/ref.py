"""Pure-jnp oracle for the flash attention kernel: materializes full scores."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Skv, Hk, D)
    v: jnp.ndarray,
    *,
    causal: bool = False,
    kv_mask: Optional[jnp.ndarray] = None,  # (B, Skv)
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    hk = k.shape[2]
    group = h // hk
    scale = scale if scale is not None else d ** -0.5
    kr = jnp.repeat(k, group, axis=2)
    vr = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr, preferred_element_type=jnp.float32)
    logits = logits * scale
    if causal:
        qi = jnp.arange(sq)[:, None]
        ki = jnp.arange(k.shape[1])[None, :]
        logits = jnp.where((ki <= qi)[None, None], logits, NEG_INF)
    if kv_mask is not None:
        logits = jnp.where(kv_mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vr)
