"""Public flash-attention API with custom VJP.

Forward: the Pallas kernel. Backward: recompute through the pure-JAX chunked
online-softmax implementation (models/attention.py) — same blocked memory
profile, one implementation to maintain for training. (A fully-Pallas dq/dk/dv
backward is a further §Perf lever; the recompute path is the shipping
default, as in several production JAX attention stacks.)
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.models.attention import chunked_attention


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash(q, k, v, kv_mask, causal, scale, block_q, block_k, interpret):
    return flash_attention_fwd(
        q, k, v, causal=causal, kv_mask=kv_mask, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def _fwd(q, k, v, kv_mask, causal, scale, block_q, block_k, interpret):
    out = _flash(q, k, v, kv_mask, causal, scale, block_q, block_k, interpret)
    return out, (q, k, v, kv_mask)


def _bwd(causal, scale, block_q, block_k, interpret, res, g):
    q, k, v, kv_mask = res

    def f(q_, k_, v_):
        return chunked_attention(
            q_, k_, v_, causal=causal, kv_mask=kv_mask, scale=scale,
            q_chunk=block_q, kv_chunk=block_k,
        )

    _, vjp = jax.vjp(f, q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None


_flash.defvjp(_fwd, _bwd)


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = False,
    kv_mask: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    if kv_mask is None:
        kv_mask = jnp.ones((q.shape[0], k.shape[1]), dtype=bool)
    return _flash(q, k, v, kv_mask, causal, scale, block_q, block_k, interpret)
