"""Flash attention forward Pallas TPU kernel (causal + GQA + padding mask).

Grid: (batch, q_heads, Sq/block_q, Skv/block_k) — KV innermost so the
per-row online-softmax state (running max / sum-exp / weighted accumulator)
lives in VMEM scratch across the KV sweep. GQA is an index-map detail: the
KV block for q-head h comes from kv-head h // (H/Hk) — no repeated KV in HBM.

Block sizes default to (block_q=256, block_k=512) with head_dim loaded whole:
VMEM footprint = q (256 x 128 x 4B) + k,v (512 x 128 x 4B x 2) + acc
(256 x 128 x 4B) + scores (256 x 512 x 4B) ≈ 1.2 MiB — well inside the
16 MiB/core budget, MXU-aligned (multiples of 128) on both matmul dims.

Causal blocks strictly above the diagonal are masked (not skipped); the
dry-run roofline counts them, and block-skipping is listed as a §Perf lever.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(
    q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, bq, bk, n_kv_blocks,
):
    jkv = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(jkv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :]                      # (bq, D)
    k = k_ref[0, :, 0, :]                      # (bk, D)
    v = v_ref[0, :, 0, :]                      # (bk, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                  # (bq, bk)

    if causal:
        rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = jkv * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols <= rows, s, NEG_INF)
    s = jnp.where(mask_ref[0, :][None, :], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new

    @pl.when(jkv == n_kv_blocks - 1)
    def _final():
        out = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,   # (B, Sq, H, D)
    k: jnp.ndarray,   # (B, Skv, Hk, D)
    v: jnp.ndarray,
    *,
    causal: bool = False,
    kv_mask: Optional[jnp.ndarray] = None,
    scale: Optional[float] = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    _, skv, hk, _ = k.shape
    group = h // hk
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    grid = (b, h, sq // block_q, skv // block_k)
    if kv_mask is None:
        kv_mask = jnp.ones((b, skv), dtype=bool)

    kernel = functools.partial(
        _fwd_kernel,
        scale=scale,
        causal=causal,
        bq=block_q,
        bk=block_k,
        n_kv_blocks=grid[3],
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h_, i, j: (b_, i, h_, 0)),
            pl.BlockSpec(
                (1, block_k, 1, d), lambda b_, h_, i, j, g=group: (b_, j, h_ // g, 0)
            ),
            pl.BlockSpec(
                (1, block_k, 1, d), lambda b_, h_, i, j, g=group: (b_, j, h_ // g, 0)
            ),
            pl.BlockSpec((1, block_k), lambda b_, h_, i, j: (b_, j)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d), lambda b_, h_, i, j: (b_, i, h_, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kv_mask)
