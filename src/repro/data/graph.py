"""Graph data: deterministic synthetic graphs, CSR neighbor sampling (the
real sampler required by the minibatch_lg cell), molecule batches.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray   # (N+1,)
    indices: np.ndarray  # (E,)
    feat: np.ndarray     # (N, F)
    labels: np.ndarray   # (N,)
    edge_dist: Optional[np.ndarray] = None  # (E,) distances aligned w/ indices

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)


def synthetic_graph(
    n_nodes: int, avg_degree: int, d_feat: int, n_classes: int, seed: int = 0
) -> CSRGraph:
    """Deterministic scale-free-ish graph with community-correlated features."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    # preferential-ish attachment: destinations biased toward low ids
    src = rng.integers(0, n_nodes, n_edges)
    dst = (rng.pareto(1.5, n_edges) * n_nodes / 20).astype(np.int64) % n_nodes
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    labels = rng.integers(0, n_classes, n_nodes)
    centers = rng.normal(size=(n_classes, d_feat))
    feat = centers[labels] + rng.normal(scale=2.0, size=(n_nodes, d_feat))
    dist = rng.uniform(0.5, 9.5, n_edges)
    return CSRGraph(
        indptr=indptr,
        indices=dst.astype(np.int64),
        feat=feat.astype(np.float32),
        labels=labels.astype(np.int32),
        edge_dist=dist.astype(np.float32),
    )


def to_edge_list(g: CSRGraph) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(src, dst, dist) flat arrays — message direction src -> dst."""
    src = np.repeat(np.arange(g.n_nodes), np.diff(g.indptr))
    return g.indices.astype(np.int32), src.astype(np.int32), (
        g.edge_dist if g.edge_dist is not None else np.ones(g.n_edges, np.float32)
    )


def sample_blocks(
    g: CSRGraph, seeds: np.ndarray, fanouts: Sequence[int], rng: np.random.Generator
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Layer-wise uniform neighbor sampling (GraphSAGE-style), padded to the
    static worst case so the jitted step never recompiles.

    Returns (nodes, src, dst, edge_mask):
      nodes: (max_nodes,) node ids (padded with 0); seeds first.
      src/dst: (max_edges,) edge endpoints as *positions into nodes*.
      edge_mask: (max_edges,) validity.
    max_nodes = seeds*(1 + f1 + f1*f2 ...), max_edges = seeds*f1 + seeds*f1*f2 ...
    """
    frontier = np.asarray(seeds)
    all_nodes: List[np.ndarray] = [frontier]
    edge_src: List[np.ndarray] = []
    edge_dst: List[np.ndarray] = []
    # positions of current frontier within the node list
    offset = 0
    for fanout in fanouts:
        new_nodes = np.empty(len(frontier) * fanout, np.int64)
        src_pos = np.empty(len(frontier) * fanout, np.int64)
        next_offset = offset + len(frontier)
        for i, node in enumerate(frontier):
            lo, hi = g.indptr[node], g.indptr[node + 1]
            if hi > lo:
                picks = g.indices[rng.integers(lo, hi, fanout)]
            else:
                picks = np.full(fanout, node)
            new_nodes[i * fanout : (i + 1) * fanout] = picks
            src_pos[i * fanout : (i + 1) * fanout] = offset + i
        all_nodes.append(new_nodes)
        # messages flow neighbor -> frontier node
        edge_src.append(next_offset + np.arange(len(new_nodes)))
        edge_dst.append(src_pos)
        frontier = new_nodes
        offset = next_offset

    nodes = np.concatenate(all_nodes)
    src = np.concatenate(edge_src)
    dst = np.concatenate(edge_dst)
    mask = np.ones(len(src), bool)
    return nodes, src.astype(np.int32), dst.astype(np.int32), mask


def block_sizes(n_seeds: int, fanouts: Sequence[int]) -> Tuple[int, int]:
    """Static (max_nodes, max_edges) for the padded sampled block."""
    n_nodes, n_edges, layer = n_seeds, 0, n_seeds
    for f in fanouts:
        layer *= f
        n_nodes += layer
        n_edges += layer
    return n_nodes, n_edges


def molecule_batch(
    batch: int, n_atoms: int, n_edges_per: int, seed: int = 0
) -> dict:
    """Batched small molecules as one flat graph (graph_id pooling)."""
    rng = np.random.default_rng(seed)
    n = batch * n_atoms
    e = batch * n_edges_per
    src = np.empty(e, np.int32)
    dst = np.empty(e, np.int32)
    for b in range(batch):
        s = rng.integers(0, n_atoms, n_edges_per) + b * n_atoms
        d = rng.integers(0, n_atoms, n_edges_per) + b * n_atoms
        src[b * n_edges_per : (b + 1) * n_edges_per] = s
        dst[b * n_edges_per : (b + 1) * n_edges_per] = d
    return {
        "nodes": rng.integers(1, 20, n).astype(np.int32),
        "src": src,
        "dst": dst,
        "edge_dist": rng.uniform(0.7, 9.0, e).astype(np.float32),
        "node_mask": np.ones(n, bool),
        "edge_mask": np.ones(e, bool),
        "graph_id": np.repeat(np.arange(batch), n_atoms).astype(np.int32),
        "n_graphs": batch,
        "targets": rng.normal(size=batch).astype(np.float32),
    }
