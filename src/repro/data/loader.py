"""Deterministic sharded data loader with checkpointable state.

Index stream: per-epoch permutation keyed by (seed, epoch); each host takes a
strided slice (host_id :: n_hosts) of every global batch, so the union over
hosts is exactly the global batch and elastic re-partitioning (different
n_hosts on resume) replays the same global sample sequence (tested).

State = (epoch, step) plus the mined-table staleness stamps — four ints,
saved with the checkpoint. A background prefetch thread overlaps host-side
batch assembly with device compute; ``MinedNegativeInjector`` joins the
mining subsystem's double-buffered ``NegativeTable`` (repro/mining) into
batch assembly as extra hard-negative columns.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, Optional

import numpy as np


@dataclasses.dataclass
class LoaderState:
    epoch: int = 0
    step: int = 0  # step within epoch
    # staleness stamps of the last mined NegativeTable batches were joined
    # against (repro/mining): the training step whose params mined it (-1 =
    # no table yet) and its monotonic version. Checkpointed so a restored
    # run can tell how stale its restored negatives are.
    mined_step: int = -1
    mined_version: int = 0

    def to_dict(self):
        return {
            "epoch": self.epoch,
            "step": self.step,
            "mined_step": self.mined_step,
            "mined_version": self.mined_version,
        }

    @staticmethod
    def from_dict(d):
        # .get: dicts saved before the mining stamps existed restore cleanly
        return LoaderState(
            epoch=int(d["epoch"]),
            step=int(d["step"]),
            mined_step=int(d.get("mined_step", -1)),
            mined_version=int(d.get("mined_version", 0)),
        )


class ShardedLoader:
    def __init__(
        self,
        dataset_size: int,
        global_batch: int,
        *,
        seed: int = 0,
        host_id: int = 0,
        n_hosts: int = 1,
        drop_last: bool = True,
        state: Optional[LoaderState] = None,
    ):
        assert global_batch % n_hosts == 0
        self.dataset_size = dataset_size
        self.global_batch = global_batch
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.steps_per_epoch = dataset_size // global_batch
        assert self.steps_per_epoch > 0, "dataset smaller than one global batch"
        self.state = state or LoaderState()

    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(self.dataset_size)

    def next_indices(self) -> np.ndarray:
        """Local (this host's) index slice of the next global batch."""
        st = self.state
        perm = self._epoch_perm(st.epoch)
        lo = st.step * self.global_batch
        batch = perm[lo : lo + self.global_batch]
        local = batch[self.host_id :: self.n_hosts]
        st.step += 1
        if st.step >= self.steps_per_epoch:
            st.step = 0
            st.epoch += 1
        return local

    def global_indices_for(self, epoch: int, step: int) -> np.ndarray:
        perm = self._epoch_perm(epoch)
        lo = step * self.global_batch
        return perm[lo : lo + self.global_batch]


class PrefetchIterator:
    """Wrap a () -> batch callable with a depth-k background prefetch thread."""

    def __init__(self, fn: Callable[[], Dict[str, np.ndarray]], depth: int = 2):
        self._fn = fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None
        self._exc_delivered = False
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            while not self._stop.is_set():
                item = self._fn()
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # surfaced on next __next__
            self._exc = e

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            if self._exc is not None:
                self._exc_delivered = True
                raise self._exc
            try:
                return self._q.get(timeout=0.5)
            except queue.Empty:
                continue

    def close(self):
        """Stop the worker — and surface a worker failure the consumer never
        saw: a crash after the consumer's last __next__ would otherwise be
        silently swallowed by the shutdown path."""
        self._stop.set()
        self._thread.join(timeout=2.0)
        if self._exc is not None and not self._exc_delivered:
            self._exc_delivered = True
            raise self._exc


class MinedNegativeInjector:
    """Join the miner's published ``NegativeTable`` into batch assembly.

    ``read_table`` is the buffer read (``miner.buffer.read``) — called once
    per batch, so the whole batch sees one consistent snapshot even if the
    background refresh swaps mid-assembly. Empty slots (-1: pre-first-
    refresh, or an under-filled teleportation band) fall back to seeded
    uniform non-gold corpus ids keyed by (seed, step) — deterministic, so
    the synchronous-mode trajectory is bit-reproducible and shapes stay
    static.

    When handed the loader's ``state``, each call stamps the staleness
    fields (``mined_step``/``mined_version``) so they ride the checkpoint;
    ``on_step`` (``miner.note_step``) tells the miner how far training has
    advanced — the refresh-overlap metric.
    """

    def __init__(
        self,
        read_table: Callable[[], "object"],
        n_passages: int,
        *,
        n_negatives: Optional[int] = None,
        seed: int = 0,
        state: Optional[LoaderState] = None,
        on_step: Optional[Callable[[int], None]] = None,
    ):
        self._read = read_table
        self.n_passages = n_passages
        self.n_negatives = n_negatives
        self.seed = seed
        self.state = state
        self.on_step = on_step

    def mined_ids(
        self, query_idx: np.ndarray, gold: np.ndarray, step: int
    ) -> np.ndarray:
        """(B, n_negatives) int32 passage ids for this batch's queries."""
        if self.on_step is not None:
            self.on_step(step)
        table = self._read()  # one atomic read per batch
        query_idx = np.asarray(query_idx)
        gold = np.asarray(gold)
        width = (
            table.ids.shape[1] if self.n_negatives is None else self.n_negatives
        )
        rows = np.full((len(query_idx), width), -1, np.int32)
        take = min(width, table.ids.shape[1])
        rows[:, :take] = table.ids[query_idx][:, :take]
        # deterministic non-gold fallback: sample [0, n-1) and shift past the
        # gold id — uniform over the other n-1 passages
        rng = np.random.default_rng((self.seed, int(step)))
        draw = rng.integers(0, self.n_passages - 1, size=rows.shape)
        draw = draw + (draw >= gold[:, None])
        rows = np.where(rows >= 0, rows, draw).astype(np.int32)
        if self.state is not None:
            self.state.mined_step = int(table.step)
            self.state.mined_version = int(table.version)
        return rows
