"""Deterministic sharded data loader with checkpointable state.

Index stream: per-epoch permutation keyed by (seed, epoch); each host takes a
strided slice (host_id :: n_hosts) of every global batch, so the union over
hosts is exactly the global batch and elastic re-partitioning (different
n_hosts on resume) replays the same global sample sequence (tested).

State = (epoch, step) — two ints, saved with the checkpoint. A background
prefetch thread overlaps host-side batch assembly with device compute.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, Optional

import numpy as np


@dataclasses.dataclass
class LoaderState:
    epoch: int = 0
    step: int = 0  # step within epoch

    def to_dict(self):
        return {"epoch": self.epoch, "step": self.step}

    @staticmethod
    def from_dict(d):
        return LoaderState(epoch=int(d["epoch"]), step=int(d["step"]))


class ShardedLoader:
    def __init__(
        self,
        dataset_size: int,
        global_batch: int,
        *,
        seed: int = 0,
        host_id: int = 0,
        n_hosts: int = 1,
        drop_last: bool = True,
        state: Optional[LoaderState] = None,
    ):
        assert global_batch % n_hosts == 0
        self.dataset_size = dataset_size
        self.global_batch = global_batch
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.steps_per_epoch = dataset_size // global_batch
        assert self.steps_per_epoch > 0, "dataset smaller than one global batch"
        self.state = state or LoaderState()

    def _epoch_perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(self.dataset_size)

    def next_indices(self) -> np.ndarray:
        """Local (this host's) index slice of the next global batch."""
        st = self.state
        perm = self._epoch_perm(st.epoch)
        lo = st.step * self.global_batch
        batch = perm[lo : lo + self.global_batch]
        local = batch[self.host_id :: self.n_hosts]
        st.step += 1
        if st.step >= self.steps_per_epoch:
            st.step = 0
            st.epoch += 1
        return local

    def global_indices_for(self, epoch: int, step: int) -> np.ndarray:
        perm = self._epoch_perm(epoch)
        lo = step * self.global_batch
        return perm[lo : lo + self.global_batch]


class PrefetchIterator:
    """Wrap a () -> batch callable with a depth-k background prefetch thread."""

    def __init__(self, fn: Callable[[], Dict[str, np.ndarray]], depth: int = 2):
        self._fn = fn
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            while not self._stop.is_set():
                item = self._fn()
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # surfaced on next __next__
            self._exc = e

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            if self._exc is not None:
                raise self._exc
            try:
                return self._q.get(timeout=0.5)
            except queue.Empty:
                continue

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
