"""Data substrates: deterministic synthetic corpora (offline container has no
real datasets), DPR-format adapters, sharded loaders with checkpointable
state, CSR neighbor sampling, criteo-like click logs."""
