"""Criteo-like synthetic click logs with a planted logistic model, so recsys
training losses actually decrease and AUC-style checks are meaningful."""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class ClickLogGenerator:
    vocab_sizes: Tuple[int, ...]
    n_dense: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # planted model: per-field per-bucket logit contributions
        self._field_w = [rng.normal(scale=0.5, size=min(v, 1024)) for v in self.vocab_sizes]
        self._dense_w = rng.normal(scale=0.3, size=self.n_dense)
        self._zipf_a = 1.2

    def batch(self, batch_size: int, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        dense = rng.normal(size=(batch_size, self.n_dense)).astype(np.float32)
        sparse = np.empty((batch_size, len(self.vocab_sizes)), np.int64)
        logit = dense @ self._dense_w
        for f, v in enumerate(self.vocab_sizes):
            # zipfian ids (hot rows dominate, like real CTR logs)
            ids = (rng.zipf(self._zipf_a, batch_size) - 1) % v
            sparse[:, f] = ids
            logit += self._field_w[f][ids % len(self._field_w[f])]
        prob = 1.0 / (1.0 + np.exp(-(logit - logit.mean())))
        labels = (rng.random(batch_size) < prob).astype(np.float32)
        return {
            "dense": dense,
            "sparse": sparse.astype(np.int32),
            "labels": labels,
        }
