"""Retrieval training data.

Two sources:
  * ``SyntheticRetrievalCorpus`` — deterministic planted-relevance corpus:
    each passage is a token sequence; its query is a noisy subsequence
    (lexical signal a BERT-style encoder can learn); hard negatives share a
    topic prefix with the positive. Used by tests and by the paper-table
    benchmarks (the real NQ/TriviaQA/MS-Marco corpora are not
    redistributable offline; see DESIGN.md §7.4).
  * ``load_dpr_json`` — adapter for DPR-preprocessed JSON (queries with
    positive_ctxs / hard_negative_ctxs), with a hashing tokenizer so the
    pipeline runs without a vocab file.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Tuple

import numpy as np


def hash_tokenize(text: str, vocab_size: int, max_len: int, *, cls_id: int = 1) -> np.ndarray:
    """Deterministic hashing tokenizer: word -> stable id in [10, vocab)."""
    ids = [cls_id]
    for w in text.lower().split()[: max_len - 1]:
        ids.append(10 + (hash(w) & 0x7FFFFFFF) % (vocab_size - 10))
    out = np.zeros((max_len,), np.int32)
    out[: len(ids)] = ids
    return out


@dataclasses.dataclass
class SyntheticRetrievalCorpus:
    n_passages: int = 2048
    vocab_size: int = 1000
    q_len: int = 16
    p_len: int = 32
    n_topics: int = 32
    n_hard: int = 1
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # topic prefix (first 4 tokens) + content
        self.topics = rng.integers(10, self.vocab_size, size=(self.n_topics, 4))
        topic_of = rng.integers(0, self.n_topics, size=self.n_passages)
        self.passages = np.zeros((self.n_passages, self.p_len), np.int32)
        self.passages[:, 0] = 1  # CLS
        self.passages[:, 1:5] = self.topics[topic_of]
        self.passages[:, 5:] = rng.integers(
            10, self.vocab_size, size=(self.n_passages, self.p_len - 5)
        )
        self.topic_of = topic_of
        # queries: noisy subsequences of their positive passage
        self.queries = np.zeros((self.n_passages, self.q_len), np.int32)
        self.queries[:, 0] = 1
        for i in range(self.n_passages):
            take = rng.choice(
                np.arange(1, self.p_len), size=self.q_len - 1, replace=False
            )
            q = self.passages[i, np.sort(take)].copy()
            flip = rng.random(self.q_len - 1) < 0.1
            q[flip] = rng.integers(10, self.vocab_size, size=int(flip.sum()))
            self.queries[i, 1:] = q
        # hard negatives: same topic, different passage
        self.hard = np.zeros((self.n_passages, self.n_hard), np.int32)
        for i in range(self.n_passages):
            same = np.flatnonzero(topic_of == topic_of[i])
            same = same[same != i]
            if len(same) == 0:
                same = np.array([(i + 1) % self.n_passages])
            self.hard[i] = rng.choice(same, size=self.n_hard, replace=True)

    def batch(self, idx: np.ndarray) -> Dict[str, np.ndarray]:
        """Assemble a RetrievalBatch-shaped dict of numpy arrays."""
        return {
            "query": self.queries[idx],
            "passage_pos": self.passages[idx],
            "passage_hard": self.passages[self.hard[idx]].reshape(
                len(idx), self.n_hard, self.p_len
            ),
        }

    def eval_split(self, n: int = 256) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(queries, all_passages, gold_passage_index) for top@k eval."""
        idx = np.arange(self.n_passages - n, self.n_passages)
        return self.queries[idx], self.passages, idx


def load_dpr_json(
    path: str, vocab_size: int, q_len: int = 32, p_len: int = 128, n_hard: int = 1
) -> Dict[str, np.ndarray]:
    """DPR-preprocessed JSON -> tokenized arrays (hashing tokenizer).

    Schema per item: {"question": str, "positive_ctxs": [{"text": ...}],
    "hard_negative_ctxs": [{"text": ...}]}. Items missing either list are
    dropped (the paper trains only on queries having both)."""
    with open(path) as f:
        items = json.load(f)
    qs, ps, hs = [], [], []
    for it in items:
        if not it.get("positive_ctxs") or not it.get("hard_negative_ctxs"):
            continue
        qs.append(hash_tokenize(it["question"], vocab_size, q_len))
        ps.append(hash_tokenize(it["positive_ctxs"][0]["text"], vocab_size, p_len))
        hard = [
            hash_tokenize(c["text"], vocab_size, p_len)
            for c in it["hard_negative_ctxs"][:n_hard]
        ]
        while len(hard) < n_hard:
            hard.append(hard[-1])
        hs.append(np.stack(hard))
    return {
        "query": np.stack(qs),
        "passage_pos": np.stack(ps),
        "passage_hard": np.stack(hs),
    }
