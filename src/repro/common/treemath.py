"""Small pytree math helpers used across optimizers / update builders.

Kept dependency-free (no optax in this environment) and jit-friendly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    """Elementwise a + b over matching pytrees."""
    return jax.tree_util.tree_map(jnp.add, a, b)


def tree_scale(a, s):
    """Scale every leaf of ``a`` by scalar ``s``."""
    return jax.tree_util.tree_map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_global_norm(a):
    """Global L2 norm across all leaves (as used for gradient clipping)."""
    leaves = jax.tree_util.tree_leaves(a)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_cast(a, dtype):
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), a)


def tree_size(a) -> int:
    """Total number of elements across all leaves (python int; trace-safe on shapes)."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(a))
