from repro.common.treemath import (
    tree_add,
    tree_scale,
    tree_zeros_like,
    tree_global_norm,
    tree_cast,
    tree_size,
)

__all__ = [
    "tree_add",
    "tree_scale",
    "tree_zeros_like",
    "tree_global_norm",
    "tree_cast",
    "tree_size",
]
