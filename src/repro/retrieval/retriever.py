"""Retriever: one sharded, precision-aware inference surface.

The serving mirror of the StepProgram design (core/step_program.py): a
``Retriever`` composes three pluggable layers —

  * an ``IndexStore`` (index.py) — the encoded corpus in the policy's index
    dtype, replicated or sharded row-blocks over the DP mesh;
  * a ``SearchBackend`` (search.py) — dense blocked-scan vs the fused Pallas
    QK^T + running-top-k kernel;
  * the query tower of the training ``DualEncoder`` — the *same* params,
    precision policy and (under shard_map) mesh machinery as training, which
    is what ANCE-style periodic re-encode/search requires.

Replicated layout: one jitted ``encode -> topk`` program. Sharded layout:
the same program under shard_map — each device scores its local ``rows/D``
index block (gather-free: the index never moves), candidates merge with one
psum (each shard deposits its (Q, k) block into its slice of a zeros
(Q, D, k) buffer; the psum assembles all slices, a final ``top_k`` over the
D*k candidates reduces them). Slices are shard-major, so ties still break
toward the lowest global id — sharded ids match replicated bit-for-bit
(tests/test_retrieval.py).

Select everything from ``RetrieverConfig``: top-k, search backend, index
layout, precision. ``launch/serve.py`` exposes the same axes as CLI flags.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dist import DistCtx, get_shard_map
from repro.core.precision import PrecisionPolicy, resolve_precision
from repro.core.types import DualEncoder
from repro.kernels.fused_infonce.fused_infonce import NEG_INF
from repro.retrieval.index import IndexStore, build_index_store
from repro.retrieval.search import SearchBackend, resolve_search_backend


@dataclasses.dataclass(frozen=True)
class RetrieverConfig:
    """Configuration of the inference surface (mirrors ContrastiveConfig).

    top_k: results per query.
    search_impl: 'dense' | 'fused' — how one device scores its index block
        (search.py SearchBackend; same switch shape as cfg.loss_impl).
    index_layout: 'replicated' | 'sharded' — whether every device holds all
        N index rows or a contiguous rows/D block over the DP mesh
        (requires a mesh; same lever as cfg.shard_banks).
    precision: PrecisionPolicy or preset name (core/precision.py). Queries
        are scored in ``compute_dtype``, the index is stored in
        ``bank_dtype`` (persistent HBM, like the bank rings), scores are
        always fp32 (the backend contract).
    index_dtype: explicit index-buffer dtype override; None defers to the
        policy (set the policy, not this — mirrors cfg.bank_dtype).
    score_block: dense backend column-block size (peak transient is
        Q x score_block).
    block_q/block_n: fused backend VMEM tile sizes.
    encode_batch: offline corpus-encode batch (one compiled shape).
    dp_axis: mesh axis name the sharded layout shards over.
    """

    top_k: int = 20
    search_impl: str = "dense"
    index_layout: str = "replicated"
    precision: Any = "fp32"
    index_dtype: Any = None
    score_block: int = 65536
    block_q: int = 128
    block_n: int = 128
    encode_batch: int = 256
    dp_axis: str = "data"

    def resolved_precision(self) -> PrecisionPolicy:
        return resolve_precision(self.precision)

    def resolved_index_dtype(self):
        if self.index_dtype is not None:
            return self.index_dtype
        return self.resolved_precision().bank_dtype

    def resolve_backend(self) -> SearchBackend:
        if self.search_impl == "dense":
            return resolve_search_backend("dense", block=self.score_block)
        if self.search_impl == "fused":
            return resolve_search_backend(
                "fused", block_q=self.block_q, block_n=self.block_n
            )
        return resolve_search_backend(self.search_impl)


def make_dp_mesh(dp: int, axis: str = "data"):
    """A 1-D DP mesh over the first ``dp`` local devices (the serving
    counterpart of launch/train.py's --dp mesh)."""
    from jax.sharding import Mesh

    if jax.device_count() < dp:
        raise ValueError(
            f"sharded index needs >= {dp} devices (have {jax.device_count()}; "
            f"on CPU set XLA_FLAGS=--xla_force_host_platform_device_count={dp})"
        )
    return Mesh(np.array(jax.devices()[:dp]), (axis,))


class Retriever:
    """Built from the training stack's pieces: a DualEncoder (+ its params,
    typically restored from a trainer checkpoint — serving.load_trained_params),
    a RetrieverConfig, and (for the sharded layout) the DP mesh."""

    def __init__(
        self,
        encoder: DualEncoder,
        params: Any,
        cfg: RetrieverConfig = RetrieverConfig(),
        *,
        mesh=None,
        index: Optional[IndexStore] = None,
    ):
        if cfg.index_layout not in ("replicated", "sharded"):
            raise ValueError(
                f"unknown index_layout {cfg.index_layout!r}; "
                "one of ['replicated', 'sharded']"
            )
        if cfg.index_layout == "sharded" and mesh is None:
            raise ValueError(
                "index_layout='sharded' needs a mesh (make_dp_mesh(D)); "
                "the index rows shard over its DP axis"
            )
        self.encoder = encoder
        self.params = params
        self.cfg = cfg
        self.mesh = mesh
        self.backend = cfg.resolve_backend()
        self.policy = cfg.resolved_precision()
        self.shards = (
            int(mesh.shape[cfg.dp_axis]) if cfg.index_layout == "sharded" else 1
        )
        self.index = index
        self._encode_p = jax.jit(encoder.encode_passage)
        self._search_tokens = None   # jit cache, built on first search
        self._search_reps = None

    # ---------------------------------------------------------- index build
    def build_index(self, passages: np.ndarray) -> IndexStore:
        """Offline corpus build with the passage tower (fixed-batch encode,
        index rows stored in the policy's index dtype). Under the sharded
        layout the store is *placed* sharded — each device holds only its
        rows/D block persistently (the 1/D HBM claim), and search consumes
        it without resharding. Rebuilding with the current ``self.params``
        is the ANCE periodic re-encode; the jitted search programs persist
        across rebuilds (they retrace only if the index shape changes)."""
        store = build_index_store(
            lambda toks: self._encode_p(self.params, jnp.asarray(toks)),
            passages,
            batch=self.cfg.encode_batch,
            dtype=self.cfg.resolved_index_dtype(),
            shards=self.shards,
        )
        if self.cfg.index_layout == "sharded":
            # one device_put straight from the host store into the sharded
            # layout: each device pulls only its rows/D block — the full
            # matrix never lands on any single device
            from jax.sharding import NamedSharding, PartitionSpec as P

            ax = self.cfg.dp_axis
            store = store._replace(
                reps=jax.device_put(
                    store.reps, NamedSharding(self.mesh, P(ax, None))
                ),
                row_valid=jax.device_put(
                    store.row_valid, NamedSharding(self.mesh, P(ax))
                ),
            )
        else:
            store = store._replace(
                reps=jnp.asarray(store.reps),
                row_valid=jnp.asarray(store.row_valid),
            )
        self.index = store
        return self.index

    # -------------------------------------------------------------- search
    def _local_topk(self, q_reps, reps, row_valid, shard_index):
        """One device's exact top-k over its index rows, ids globalized."""
        q_reps = self.policy.cast_compute(q_reps)
        scores, ids = self.backend.topk(
            q_reps, reps, self.cfg.top_k, col_valid=row_valid
        )
        offset = jnp.asarray(shard_index, jnp.int32) * reps.shape[0]
        return scores, jnp.where(ids >= 0, ids + offset, -1)

    def _merge_shards(self, scores, ids, shard_index, ctx: DistCtx):
        """psum top-k merge: deposit this shard's (Q, k) candidates into its
        slice of a zeros (Q, D, k) buffer; the psum assembles every slice
        exactly once, a final top_k reduces D*k -> k. Slices are shard-major
        so ties break toward the lowest global id, matching replicated."""
        q, k = scores.shape
        d = self.shards
        buf_s = jnp.zeros((q, d, k), scores.dtype)
        buf_i = jnp.zeros((q, d, k), ids.dtype)
        start = (0, shard_index, 0)
        buf_s = jax.lax.dynamic_update_slice(buf_s, scores[:, None, :], start)
        buf_i = jax.lax.dynamic_update_slice(buf_i, ids[:, None, :], start)
        cat_s = ctx.psum(buf_s).reshape(q, d * k)
        cat_i = ctx.psum(buf_i).reshape(q, d * k)
        top_s, pos = jax.lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, pos, axis=1)
        return top_s, jnp.where(top_s > NEG_INF / 2, top_i, -1)

    def _build_search(self, encode: bool):
        cfg = self.cfg

        def local(params, reps, row_valid, queries, shard_index, ctx):
            q_reps = (
                self.encoder.encode_query(params, queries) if encode else queries
            )
            scores, ids = self._local_topk(q_reps, reps, row_valid, shard_index)
            if cfg.index_layout == "sharded":
                scores, ids = self._merge_shards(scores, ids, shard_index, ctx)
            return ids, scores

        if cfg.index_layout == "replicated":
            return jax.jit(
                lambda params, reps, row_valid, queries: local(
                    params, reps, row_valid, queries, 0, DistCtx()
                )
            )

        from jax.sharding import PartitionSpec as P

        ax = cfg.dp_axis
        ctx = DistCtx(ax)

        def sharded(params, reps, row_valid, queries):
            # queries replicated: every device encodes the (small) serving
            # batch; the index (the big operand) never moves
            return local(params, reps, row_valid, queries, ctx.shard_index(), ctx)

        sm, sm_kw = get_shard_map()
        return jax.jit(
            sm(
                sharded,
                mesh=self.mesh,
                in_specs=(P(), P(ax, None), P(ax), P()),
                out_specs=(P(), P()),
                **sm_kw,
            )
        )

    def _require_index(self) -> IndexStore:
        if self.index is None:
            raise ValueError("no index built yet: call build_index(passages)")
        return self.index

    def search(self, query_tokens) -> Tuple[np.ndarray, np.ndarray]:
        """Encode query tokens with the query tower and return
        (ids (Q, k) int32, scores (Q, k) fp32); ids -1 = empty slot."""
        store = self._require_index()
        if self._search_tokens is None:
            self._search_tokens = self._build_search(encode=True)
        ids, scores = self._search_tokens(
            self.params, store.reps, store.row_valid, jnp.asarray(query_tokens)
        )
        return np.asarray(ids), np.asarray(scores)

    def search_reps(self, q_reps) -> Tuple[np.ndarray, np.ndarray]:
        """Search pre-encoded query representations (Q, d)."""
        store = self._require_index()
        if self._search_reps is None:
            self._search_reps = self._build_search(encode=False)
        ids, scores = self._search_reps(
            self.params, store.reps, store.row_valid, jnp.asarray(q_reps)
        )
        return np.asarray(ids), np.asarray(scores)
