"""IndexStore: the encoded corpus, precision-aware and shardable.

The offline half of serving (ANCE-style: the corpus is periodically
re-encoded with the *training-time* passage tower). ``build_index_store``
runs the fixed-batch host encode loop (one compiled shape for the whole
corpus) and stores the matrix in the PrecisionPolicy's ``bank_dtype`` — the
index is persistent HBM exactly like the memory-bank rings, so it rides the
same dtype lever (bf16 index = half the bytes, scores stay fp32 at the
backend contract).

Two layouts, mirroring the bank modes (``cfg.shard_banks``):

  * **replicated** — every device holds all N rows.
  * **sharded** — rows are padded to a multiple of the DP shard count and
    split into contiguous row blocks; under shard_map each device scores its
    own ``rows/D`` block locally (gather-free — the index never moves) and
    the per-device top-k candidates are merged with one psum
    (retriever.py). Per-device index HBM shrinks by 1/D at identical
    results: ids match the replicated layout bit-for-bit.

Padding rows are zeros with ``row_valid`` False, so they are masked exactly
(score NEG_INF, never a candidate) rather than approximately.
"""

from __future__ import annotations

from typing import Any, Callable, List, NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.precision import resolve_precision


class IndexStore(NamedTuple):
    """Encoded corpus in its global layout.

    reps:      (rows, d) — row-major corpus representations, ``rows`` padded
               up to a multiple of ``shards``; dtype = the policy's index
               (bank) dtype. Host numpy as built by ``build_index_store``;
               the Retriever places it (replicated device array, or sharded
               row blocks via one NamedSharding device_put).
    row_valid: (rows,) bool — False for padding rows.
    n_total:   real corpus size (== row_valid.sum()).
    shards:    DP shard count this store is laid out for (1 = replicated).
    """

    reps: jnp.ndarray
    row_valid: jnp.ndarray
    n_total: int
    shards: int = 1

    @property
    def rows_per_shard(self) -> int:
        return self.reps.shape[0] // self.shards

    def bytes_per_device(self) -> int:
        """Persistent index HBM per device — the serving memory axis the
        precision policy and sharding exist to cut."""
        return (
            self.reps.shape[0]
            * self.reps.shape[1]
            * jnp.dtype(self.reps.dtype).itemsize
        ) // self.shards


def encode_corpus(
    encode_passage: Callable[[Any], jnp.ndarray],
    passages: np.ndarray,
    *,
    batch: int = 256,
) -> np.ndarray:
    """Encode a corpus in fixed batches (pads the tail so one compiled shape
    serves the whole build). Returns host fp32-or-compute-dtype rows."""
    n = len(passages)
    out: List[np.ndarray] = []
    for lo in range(0, n, batch):
        chunk = passages[lo : lo + batch]
        if len(chunk) < batch:
            chunk = np.concatenate(
                [chunk, np.repeat(chunk[-1:], batch - len(chunk), axis=0)]
            )
        out.append(np.asarray(encode_passage(chunk)))
    return np.concatenate(out)[:n]


def build_index_store(
    encode_passage: Callable[[Any], jnp.ndarray],
    passages: np.ndarray,
    *,
    batch: int = 256,
    dtype: Any = None,
    shards: int = 1,
) -> IndexStore:
    """Host-side index build: encode, cast to the index dtype, pad rows to a
    multiple of ``shards`` (padding masked via ``row_valid``).

    The returned arrays stay on the *host* (numpy; the bf16 cast goes
    through ml_dtypes): the full matrix must never land on one device —
    at the scales the sharded layout targets it would not fit. Placement
    (replicated device array or one device_put straight into the sharded
    layout, each device pulling only its rows/D block) is the Retriever's
    job (retriever.build_index). ``dtype=None`` stores at the default
    policy's bank dtype (fp32); pass ``policy.bank_dtype`` to match a run."""
    if dtype is None:
        dtype = resolve_precision(None).bank_dtype
    reps = encode_corpus(encode_passage, passages, batch=batch)
    n = reps.shape[0]
    rows = ((n + shards - 1) // shards) * shards
    valid = np.zeros((rows,), bool)
    valid[:n] = True
    if rows > n:
        reps = np.concatenate(
            [reps, np.zeros((rows - n, reps.shape[1]), reps.dtype)]
        )
    return IndexStore(
        reps=reps.astype(jnp.dtype(dtype)),
        row_valid=valid,
        n_total=n,
        shards=shards,
    )
