"""Retriever API: the inference half of the framework (see retriever.py).

One sharded, precision-aware surface from index build to serving and eval:

  RetrieverConfig -> Retriever(encoder, params) over an IndexStore and a
  SearchBackend; serving.load_trained_params / serving.make_server close
  the trainer-checkpoint -> serve loop.
"""

from repro.retrieval.index import IndexStore, build_index_store, encode_corpus
from repro.retrieval.retriever import Retriever, RetrieverConfig, make_dp_mesh
from repro.retrieval.search import (
    SEARCH_BACKENDS,
    DenseSearchBackend,
    FusedSearchBackend,
    SearchBackend,
    resolve_search_backend,
)
from repro.retrieval.serving import load_trained_params, make_server

__all__ = [
    "IndexStore", "build_index_store", "encode_corpus",
    "Retriever", "RetrieverConfig", "make_dp_mesh",
    "SEARCH_BACKENDS", "DenseSearchBackend", "FusedSearchBackend",
    "SearchBackend", "resolve_search_backend",
    "load_trained_params", "make_server",
]
