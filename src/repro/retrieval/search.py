"""Pluggable search backends: how one device scores queries against its
index rows (the serving mirror of core/loss.py's LossBackend).

A ``SearchBackend`` computes exact top-k over one index block:

  * ``dense`` (default) — blocked matmul + running ``lax.top_k`` merge
    (``jax.lax.scan`` over column blocks of ``block`` rows): never
    materializes the (Q, N) score matrix, peak transient is the (Q, block)
    tile plus the (Q, k) running best.
  * ``fused`` — the blocked Pallas kernel (kernels/fused_topk): QK^T tiles
    stream through VMEM with an in-kernel running top-k, reusing the
    fused-infonce streaming machinery. Runs under ``interpret=True`` off-TPU
    so the whole serving matrix is CPU-testable.

Shared contract (pinned by tests/test_retrieval.py):

  * scores come back fp32 whatever dtype queries/index arrive in (bf16
    compute/index under the bf16 policies) — the serving counterpart of the
    LossBackend fp32-stats contract;
  * ids are *local* column indices, int32, ties broken toward the lowest id
    (``lax.top_k`` over the full row); the Retriever adds the shard's global
    row offset;
  * ``col_valid`` masks columns exactly (corpus padding, unfilled shard
    slots); slots with no valid candidate (k > n_valid) return score
    ``NEG_INF`` and id ``-1``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.precision import SCORE_DTYPE
from repro.kernels.fused_infonce.fused_infonce import NEG_INF


class SearchBackend(Protocol):
    """Exact top-k of one query block against one index block."""

    name: str

    def topk(
        self,
        q_reps: jnp.ndarray,     # (Q, d) query representations
        index: jnp.ndarray,      # (N, d) index rows (this device's block)
        k: int,
        *,
        col_valid: Optional[jnp.ndarray] = None,  # (N,) bool
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (scores (Q, k) fp32, ids (Q, k) int32, -1 = empty)."""
        ...


@dataclasses.dataclass(frozen=True)
class DenseSearchBackend:
    """Blocked-scan exact top-k: one (Q, block) score tile at a time."""

    block: int = 65536

    name = "dense"

    def topk(self, q_reps, index, k, *, col_valid=None):
        n = index.shape[0]
        block = max(min(self.block, n), 1)
        n_blocks = (n + block - 1) // block
        pad = n_blocks * block - n
        valid = (
            jnp.ones((n,), bool) if col_valid is None else col_valid
        )
        if pad:
            index = jnp.pad(index, ((0, pad), (0, 0)))
            valid = jnp.pad(valid, (0, pad))
        blocks = index.reshape(n_blocks, block, -1)
        vblocks = valid.reshape(n_blocks, block)
        q = q_reps.shape[0]

        def body(carry, inp):
            best_s, best_i = carry
            blk, vld, b0 = inp
            s = jax.lax.dot_general(
                q_reps, blk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            ids = b0 + jnp.arange(block, dtype=jnp.int32)
            s = jnp.where(vld[None, :], s, NEG_INF)
            ids = jnp.where(vld, ids, -1)
            # running best first: ties break toward earlier column blocks,
            # matching lax.top_k over the full row
            cat_s = jnp.concatenate([best_s, s], axis=1)
            cat_i = jnp.concatenate(
                [best_i, jnp.broadcast_to(ids[None, :], s.shape)], axis=1
            )
            top_s, pos = jax.lax.top_k(cat_s, k)
            return (top_s, jnp.take_along_axis(cat_i, pos, axis=1)), None

        init = (
            jnp.full((q, k), NEG_INF, SCORE_DTYPE),
            jnp.full((q, k), -1, jnp.int32),
        )
        offsets = jnp.arange(n_blocks, dtype=jnp.int32) * block
        (scores, ids), _ = jax.lax.scan(body, init, (blocks, vblocks, offsets))
        return scores, ids


@dataclasses.dataclass(frozen=True)
class FusedSearchBackend:
    """Blocked Pallas QK^T + in-kernel running top-k (kernels/fused_topk).
    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere."""

    block_q: int = 128
    block_n: int = 128
    interpret: Optional[bool] = None

    name = "fused"

    def topk(self, q_reps, index, k, *, col_valid=None):
        from repro.kernels.fused_topk.ops import fused_topk_scores

        return fused_topk_scores(
            q_reps, index, k, col_valid=col_valid,
            block_q=self.block_q, block_n=self.block_n,
            interpret=self.interpret,
        )


SEARCH_BACKENDS = {"dense": DenseSearchBackend, "fused": FusedSearchBackend}


def resolve_search_backend(
    spec: Union[None, str, SearchBackend] = None, **kwargs
) -> SearchBackend:
    """None -> dense; a registered name -> fresh instance (kwargs forwarded);
    an instance -> as is. Raises ValueError for unknown names."""
    if spec is None:
        return DenseSearchBackend(**kwargs)
    if isinstance(spec, str):
        if spec not in SEARCH_BACKENDS:
            raise ValueError(
                f"unknown search_impl {spec!r}; one of {sorted(SEARCH_BACKENDS)}"
            )
        return SEARCH_BACKENDS[spec](**kwargs)
    return spec
