"""Serving layer: trainer checkpoints -> Retriever -> dynamic batching.

Closes the training/inference loop the ANCE recipe requires: the dual
encoder that ``runtime/trainer.py`` checkpoints is the one that builds the
index and answers queries. ``load_trained_params`` restores the params
subtree straight from a trainer checkpoint *without* a template pytree —
the checkpoint manifests are path-keyed (``state/params/query/embed/word``
...), so serving never has to reconstruct the optimizer state, banks or
loader state it does not need.

``make_server`` rebuilds the old ``make_retrieval_server`` on
``Retriever.search``: the BatchingServer (runtime/server.py) coalesces
single-query requests up to the compiled batch shape; the retriever's
jitted encode + top-k program answers each coalesced batch.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.checkpoint.checkpoint import latest_step
from repro.retrieval.retriever import Retriever
from repro.runtime.server import BatchingServer

PARAMS_PREFIX = "state/params/"


def load_trained_params(
    ckpt_dir: str, step: Optional[int] = None
) -> Tuple[Any, int]:
    """(params, step) from a runtime/trainer.py checkpoint directory.

    Reads the path-keyed manifest of the requested (default: latest valid)
    checkpoint and rebuilds only the ``state/params/...`` subtree as nested
    dicts of numpy arrays — dtype and shape exactly as trained (fp32
    masters under every shipped PrecisionPolicy preset). The optimizer
    state, memory banks and loader state are never touched.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:012d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    params: Dict[str, Any] = {}
    found = False
    for meta in manifest["leaves"]:
        key = meta["key"]
        if not key.startswith(PARAMS_PREFIX):
            continue
        found = True
        node = params
        parts = key[len(PARAMS_PREFIX):].split("/")
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = np.load(os.path.join(path, meta["file"]))
    if not found:
        raise ValueError(
            f"checkpoint {path} has no {PARAMS_PREFIX!r} leaves — not a "
            "trainer-produced ContrastiveState checkpoint?"
        )
    return params, step


def make_server(
    retriever: Retriever,
    *,
    max_batch: int = 32,
    max_wait_s: float = 0.01,
) -> BatchingServer:
    """Dynamic-batching server over ``Retriever.search``: requests are
    single tokenized queries; each coalesced batch runs the retriever's
    jitted encode + top-k program once."""
    retriever._require_index()

    def serve_fn(payloads: np.ndarray):
        return retriever.search(payloads)

    return BatchingServer(serve_fn, max_batch=max_batch, max_wait_s=max_wait_s)
